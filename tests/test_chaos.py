"""Crash-fault tolerance: machine failures, WC error statuses, retry
budgets, lease reclamation, remote-pager failover, chaos soaks.

The thesis assumes live endpoints; these tests pin down what the fabric
does when that assumption breaks — every affected work request must
complete exactly once with a non-SUCCESS status, nothing may retransmit
forever into a dead peer, and the PR-5 tr_ID lifecycle invariants must
survive a crash (leased orphans, generation bumps, reclamation).
"""

import pytest

from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       NetworkPartitioned, NodeDown, Strategy, WCStatus,
                       WROpcode)
from repro.testing import (FaultInjection, TenantSpec, check_crash_consistency,
                           check_link_conservation, check_tr_id_lifecycle,
                           soak)
from repro.vmem.remote import RemoteFramePool

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
UNMAPPED_DST = 0x7F_0000_0000     # never mmap'd: faults can never resolve


def write_pair(dom, src_node, dst_node, size=65536,
               dst_prep=BufferPrep.TOUCHED):
    src = dom.register_memory(src_node, SRC, size, prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(dst_node, DST, size, prep=dst_prep)
    return src, dst


class TestCrashCompletions:
    def test_crash_dst_mid_rapf_completes_remote_op_err(self):
        """The hardest window: the destination NACKed (block PAUSED_DST,
        source waiting for the RAPF grant) and then dies — the grant
        never comes.  The WR must complete REMOTE_OP_ERR after the
        crash-detection rounds, never hang or retransmit forever."""
        fab = Fabric.build(FabricConfig(n_nodes=2))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = write_pair(dom, 0, 1, dst_prep=BufferPrep.FAULTING)
        wr = dom.post_write(src, dst, cq=cq)

        def crash_when_paused():        # fire exactly inside the window
            r5 = fab.nodes[0].r5
            if any(b.state.name == "PAUSED_DST"
                   for b in r5.pending.values()):
                fab.crash_node(1)
                return
            fab.loop.schedule(1.0, crash_when_paused)

        fab.loop.schedule(1.0, crash_when_paused)
        wc = wr.result()
        assert wc.status == WCStatus.REMOTE_OP_ERR
        assert not wc.ok
        assert wc.stats.dst_faults >= 1          # the NACK did arrive
        fab.progress()
        assert check_crash_consistency(fab) == []
        assert check_tr_id_lifecycle(fab) == []

    def test_crash_src_flushes_wr_flush_err(self):
        fab = Fabric.build(FabricConfig(n_nodes=2))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = write_pair(dom, 0, 1)
        wr = dom.post_write(src, dst, cq=cq)
        fab.loop.schedule(2.0, fab.crash_node, 0)
        assert wr.result().status == WCStatus.WR_FLUSH_ERR

    def test_posting_from_crashed_node_raises_node_down(self):
        fab = Fabric.build(FabricConfig(n_nodes=2))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = write_pair(dom, 0, 1)
        fab.crash_node(0)
        with pytest.raises(NodeDown):
            dom.post_write(src, dst, cq=cq)

    def test_posting_toward_crashed_peer_completes_async(self):
        """Posting *toward* a dead peer is allowed (the poster cannot
        know) — the WR completes asynchronously with REMOTE_OP_ERR."""
        fab = Fabric.build(FabricConfig(n_nodes=2))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = write_pair(dom, 0, 1)
        fab.crash_node(1)
        wc = dom.post_write(src, dst, cq=cq).result()
        assert wc.status == WCStatus.REMOTE_OP_ERR

    def test_close_domain_flushes_stranded_wrs_promptly(self):
        """The drain hang: close_domain used to spin 5e6 virtual us
        waiting for transfers a dead peer can never complete.  Stranded
        WRs must flush with WR_FLUSH_ERR and teardown stays prompt."""
        fab = Fabric.build(FabricConfig(n_nodes=2))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = write_pair(dom, 0, 1, dst_prep=BufferPrep.FAULTING)
        wr = dom.post_write(src, dst, cq=cq)
        fab.crash_node(1)
        fab.close_domain(1)              # returns promptly, no drain spin
        assert fab.now < 1e5             # not the 5e6 us drain deadline
        assert wr.result().status in (WCStatus.WR_FLUSH_ERR,
                                      WCStatus.REMOTE_OP_ERR)


class TestRetryBudget:
    def _permanently_paused_wr(self, fab, max_retries, backoff=1.0):
        """A write whose destination VA is never mmap'd: every round
        NACKs, the resolver's touch SEGFAULTs (recovered), the block
        pauses and retries forever — unless a budget caps it."""
        dom = fab.open_domain(1, policy=FaultPolicy(
            strategy=Strategy.TOUCH_A_PAGE, max_retries=max_retries,
            retry_backoff=backoff))
        dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        cq.on_post()
        t = fab._start_write(1, 0, SRC, 0, UNMAPPED_DST, 4096)
        return fab._track(fab._next_wr_id(), WROpcode.WRITE, cq, t)

    def test_budget_exhaustion_completes_retry_exc_err(self):
        fab = Fabric.build(FabricConfig(n_nodes=1))
        wr = self._permanently_paused_wr(fab, max_retries=4)
        wc = wr.result()                 # finite now: budget caps the loop
        assert wc.status == WCStatus.RETRY_EXC_ERR
        assert not wc.ok
        assert wc.stats.segfaults_recovered > 0   # it really was stuck
        fab.progress()
        assert check_crash_consistency(fab) == []
        assert check_tr_id_lifecycle(fab) == []

    def test_backoff_stretches_time_to_exhaustion(self):
        def exhaust(backoff):
            fab = Fabric.build(FabricConfig(n_nodes=1))
            wr = self._permanently_paused_wr(fab, max_retries=3,
                                             backoff=backoff)
            assert wr.result().status == WCStatus.RETRY_EXC_ERR
            return fab.now

        assert exhaust(2.0) > exhaust(1.0)

    def test_unlimited_default_keeps_retrying(self):
        """max_retries=None (the default) preserves the seed's
        infinite-retry semantics — the paused WR never errors out."""
        fab = Fabric.build(FabricConfig(n_nodes=1))
        wr = self._permanently_paused_wr(fab, max_retries=None)
        with pytest.raises(TimeoutError):
            wr.result(deadline_us=25_000.0)
        assert wr.stats.timeouts > 0     # still alive, still retrying


class TestLinkFailures:
    def test_flap_on_torus_re_paths_without_duplicate_delivery(self):
        """Fail a link mid-transfer on a routed torus, restore it later:
        traffic detours, the WR still succeeds, and the per-link packet
        ledger balances — nothing lost or delivered twice."""
        fab = Fabric.build(FabricConfig(n_nodes=8, topology="torus_2d"))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src = dom.register_memory(0, SRC, 262144, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(3, DST, 262144, prep=BufferPrep.TOUCHED)
        wr = dom.post_write(src, dst, cq=cq)
        fab.loop.schedule(2.0, fab.fail_link, 0, 1)
        fab.loop.schedule(400.0, fab.restore_link, 0, 1)
        wc = wr.result()
        assert wc.ok
        fab.progress()                   # let the restore event land
        assert fab.interconnect.down == frozenset()       # fully healed
        assert check_link_conservation(fab) == []

    def test_partition_is_typed_and_detour_is_deterministic(self):
        fab = Fabric.build(FabricConfig(n_nodes=4, topology="ring"))
        ic = fab.interconnect
        clean = ic.router.route(0, 1)
        fab.fail_link(0, 1)
        detour = ic.router.route_avoiding(0, 1, ic.down)
        assert detour == (0, 3, 2, 1)     # BFS over sorted neighbors
        fab.fail_link(0, 3)               # node 0 now fully cut off
        with pytest.raises(NetworkPartitioned):
            ic.router.route_avoiding(0, 1, ic.down)
        assert not ic.reachable(0, 2)
        fab.restore_link(0, 1)
        fab.restore_link(0, 3)
        assert ic.router.route_avoiding(0, 1, ic.down) == clean


class TestLeaseReclamation:
    def test_reclaim_crosses_generation_boundary(self):
        """Shrunken tr_ID space: wrap it (recycled allocations, gen >= 2)
        *before* the crash, so the leased orphans die mid-generation.
        Reclamation must restore the free-list identity exactly."""
        fab = Fabric.build(FabricConfig(n_nodes=2, tr_id_space=2,
                                        lease_timeout_us=5_000.0))
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        # wrap the 2-ID space: 6 sequential transfers -> allocated=6,
        # wraps=3, every later ID is a recycled generation >= 2
        for i in range(6):
            src = dom.register_memory(0, SRC + i * (1 << 20), 4096,
                                      prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(1, DST + i * (1 << 20), 4096,
                                      prep=BufferPrep.TOUCHED)
            assert dom.post_write(src, dst, cq=cq).result().ok
        r5 = fab.nodes[0].r5
        assert r5.id_stats.wraps >= 2
        # two in-flight transfers, then fail-stop the source
        wrs = []
        for i in range(6, 8):
            src = dom.register_memory(0, SRC + i * (1 << 20), 4096,
                                      prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(1, DST + i * (1 << 20), 4096,
                                      prep=BufferPrep.TOUCHED)
            wrs.append(dom.post_write(src, dst, cq=cq))
        # crash a few us in, once both blocks are launched and own IDs
        fab.loop.schedule(3.0, fab.crash_node, 0)
        for wr in wrs:
            assert wr.result().status == WCStatus.WR_FLUSH_ERR
        # the orphaned IDs stay leased until the lease expires...
        assert len(r5.pending) == 2
        assert check_crash_consistency(fab) == []
        fab.progress()                   # ...then reclamation runs
        assert r5.pending == {}
        assert r5.id_stats.lease_reclaims == 2
        assert check_tr_id_lifecycle(fab) == []
        assert fab.now >= 5_000.0        # reclaim waited the lease out


class TestRemotePagerFailover:
    def _pool(self):
        return RemoteFramePool.build(
            n_frames=8, page_elems=16, n_pages=32,
            config=FabricConfig(n_nodes=4, topology="ring"),
            remote_node=1, replica_node=2)

    def test_failover_read_your_writes(self):
        pool = self._pool()
        pool.page_out(None, 0, 4)        # mirrored to primary + replica
        assert pool.page_in(None, 0, 2).failovers == 0
        pool.fabric.crash_node(1)        # primary backing node dies
        r = pool.page_in(None, 0, 4)
        assert r.failovers == 1
        assert r.bytes_in == 4 * pool.page_bytes
        assert pool.failed_over
        assert pool.ryw_verified == 4 and pool.ryw_violations == 0
        # post-failover traffic is replica-only and still works
        pool.page_out(None, 4, 2)
        assert pool.page_in(None, 4, 2).failovers == 1

    def test_failover_latency_spans_both_attempts(self):
        pool = self._pool()
        pool.page_in(None, 0, 1)         # cold read faults the landing page
        warm = pool.page_in(None, 0, 1).us
        pool.fabric.crash_node(1)
        recovery = pool.page_in(None, 0, 1)
        assert recovery.failovers == 1
        assert recovery.us > warm        # detection time is part of it

    def test_no_replica_means_failed_page_in(self):
        pool = RemoteFramePool.build(
            n_frames=8, page_elems=16, n_pages=32,
            config=FabricConfig(n_nodes=2))
        pool.fabric.crash_node(1)
        r = pool.page_in(None, 0, 1)
        assert r.failovers == 0 and r.bytes_in == 0

    def test_replica_must_be_remote_from_primary(self):
        with pytest.raises(ValueError):
            RemoteFramePool.build(
                n_frames=8, page_elems=16, n_pages=32,
                config=FabricConfig(n_nodes=4, topology="ring"),
                remote_node=1, replica_node=1)


CHAOS_CONFIG = dict(config=FabricConfig(n_nodes=8, topology="torus_2d"))
CHAOS_TENANTS = [
    TenantSpec(pd=1, name="t01", mode="closed", inflight=2, n_requests=10,
               src_node=0, dst_node=1),
    TenantSpec(pd=2, name="t23", mode="closed", inflight=2, n_requests=10,
               src_node=2, dst_node=3, dst_prep=BufferPrep.FAULTING),
    TenantSpec(pd=3, name="t32", mode="closed", inflight=2, n_requests=10,
               src_node=3, dst_node=2),
]
CHAOS_INJECTION = FaultInjection(
    khugepaged_period_us=500.0, reclaim_period_us=700.0,
    crashes=((800.0, 2),), link_flaps=((300.0, 900.0, 0, 1),))


class TestChaosSoak:
    @pytest.mark.parametrize("seed", [7, 31, 99])
    def test_seeded_chaos_soak_is_byte_identical(self, seed):
        """Crash storms + link flaps + churn: zero invariant violations,
        every affected WR completes exactly once with an error status,
        and the whole run replays byte-identically from its seed."""
        a = soak(seed, tenants=CHAOS_TENANTS, injection=CHAOS_INJECTION,
                 **CHAOS_CONFIG)
        assert a.ok, a.violations
        b = soak(seed, tenants=CHAOS_TENANTS, injection=CHAOS_INJECTION,
                 **CHAOS_CONFIG)
        assert a.json() == b.json()
        # the crash actually bit: node 2's tenants saw error completions
        by_name = {t["tenant"]: t for t in a.stats["tenants"]}
        assert by_name["t23"]["aborted"]              # posting node died
        assert by_name["t32"]["errors"] > 0           # peer died
        for t in a.stats["tenants"]:                  # exactly-once, always
            assert t["completed"] == t["posted"]

    def test_crash_free_chaos_schedule_matches_plain_injection(self):
        """Empty crash/flap schedules change nothing: the soak stats are
        byte-identical with and without the new FaultInjection fields."""
        plain = FaultInjection(khugepaged_period_us=500.0)
        wired = FaultInjection(khugepaged_period_us=500.0,
                               crashes=(), link_flaps=())
        a = soak(5, injection=plain, **CHAOS_CONFIG)
        b = soak(5, injection=wired, **CHAOS_CONFIG)
        assert a.ok and b.ok
        assert a.json() == b.json()
