"""Validation of the thesis' headline claims (DESIGN.md §1, C1–C9).

These tests pin the calibrated simulator to the paper's measured numbers;
if a core/ change shifts the mechanism's behaviour, these fail first.
"""

import pytest

from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.engine import BufferPrep
from repro.core.experiments import run_remote_write
from repro.core.firehose import FirehoseConfig, FirehoseNode
from repro.core.resolver import Strategy


def _dst_ratio(size):
    tap = run_remote_write(size, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                           strategy=Strategy.TOUCH_A_PAGE)
    ta = run_remote_write(size, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                          strategy=Strategy.TOUCH_AHEAD)
    return tap.latency_us / ta.latency_us


def _src_ratio(size):
    tap = run_remote_write(size, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                           strategy=Strategy.TOUCH_A_PAGE)
    ta = run_remote_write(size, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                          strategy=Strategy.TOUCH_AHEAD)
    return tap.latency_us / ta.latency_us


class TestC1IdealLatency:
    def test_16b_rtt_is_4us(self):
        r = run_remote_write(16, BufferPrep.TOUCHED, BufferPrep.TOUCHED)
        assert r.latency_us == pytest.approx(4.0, abs=0.25)
        assert r.stats.timeouts == 0
        assert r.stats.dst_faults == 0 and r.stats.src_faults == 0

    def test_latency_monotone_in_size(self):
        lats = [run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.TOUCHED
                                 ).latency_us
                for s in (16, 1024, 4096, 16384, 65536)]
        assert lats == sorted(lats)


class TestC2OsCosts:
    """Table 4.1 is the calibration table — reproduced exactly."""

    def test_table_4_1_exact(self):
        from repro.core.costmodel import TABLE_4_1, TABLE_4_1_SIZES
        c = DEFAULT_COST_MODEL
        for i, size in enumerate(TABLE_4_1_SIZES):
            assert c.mmap_us(size) == pytest.approx(TABLE_4_1["mmap"][i])
            assert c.pin_us(size) == pytest.approx(TABLE_4_1["pin"][i])
            assert c.unpin_us(size) == pytest.approx(TABLE_4_1["unpin"][i])
            assert c.touch_us(size) == pytest.approx(TABLE_4_1["touch"][i])
            assert c.munmap_us(size) == pytest.approx(TABLE_4_1["munmap"][i])

    def test_pin_unpin_grow_with_pages(self):
        c = DEFAULT_COST_MODEL
        assert c.pin_us(65536) > c.pin_us(16384) > c.pin_us(4096)
        assert c.touch_us(65536) > c.touch_us(4096)


class TestC3DestinationFaults:
    """Touch-Ahead/Touch-A-Page benefit 1.7x/1.2x/1.2x at 16/32/64 KB."""

    def test_16kb_ratio(self):
        assert _dst_ratio(16384) == pytest.approx(1.7, abs=0.15)

    def test_interleaving_dampens_benefit(self):
        # paper: benefit decreases at 32/64 KB due to FIFO duplicates
        r16, r32, r64 = _dst_ratio(16384), _dst_ratio(32768), _dst_ratio(65536)
        assert r32 < r16
        assert r64 == pytest.approx(1.2, abs=0.15)

    def test_sub_page_sizes_equal(self):
        # "the results seem similar up to 4KB, which is the size of one page"
        for s in (16, 256, 4096):
            tap = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                                   strategy=Strategy.TOUCH_A_PAGE)
            ta = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                                  strategy=Strategy.TOUCH_AHEAD)
            assert tap.latency_us / ta.latency_us == pytest.approx(1.0, abs=0.25)


class TestC4C5SourceFaults:
    def test_source_ratios(self):
        # paper: 3.9x / 3.9x / 4.7x — one timeout per *page* vs per *block*
        assert _src_ratio(16384) == pytest.approx(3.9, abs=0.3)
        assert _src_ratio(32768) == pytest.approx(3.9, abs=0.3)
        assert _src_ratio(65536) == pytest.approx(4.3, abs=0.6)

    def test_timeout_counts(self):
        tap = run_remote_write(16384, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                               strategy=Strategy.TOUCH_A_PAGE)
        ta = run_remote_write(16384, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                              strategy=Strategy.TOUCH_AHEAD)
        assert tap.stats.timeouts == 4   # one per 4 KB page
        assert ta.stats.timeouts == 1    # one per 16 KB block

    def test_small_transfers_dominated_by_timeout(self):
        r = run_remote_write(16, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                             strategy=Strategy.TOUCH_A_PAGE)
        assert r.stats.timeouts == 1
        assert r.latency_us == pytest.approx(
            DEFAULT_COST_MODEL.timeout_us, rel=0.15)


class TestC6SrcPlusDstFasterThanSrc:
    @pytest.mark.parametrize("size", [16384, 65536])
    def test_fewer_timeouts_and_lower_latency(self, size):
        src = run_remote_write(size, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                               strategy=Strategy.TOUCH_A_PAGE)
        both = run_remote_write(size, BufferPrep.FAULTING, BufferPrep.FAULTING,
                                strategy=Strategy.TOUCH_A_PAGE)
        assert both.stats.timeouts < src.stats.timeouts
        assert both.latency_us < src.latency_us
        # dst NACKs turned into explicit RAPF retransmissions
        assert both.stats.rapf_retransmits > 0


class TestC7TimeoutSweep:
    def test_1ms_best(self):
        lats = {to: run_remote_write(16384, BufferPrep.FAULTING,
                                     BufferPrep.TOUCHED,
                                     strategy=Strategy.TOUCH_A_PAGE,
                                     timeout_us=to).latency_us
                for to in (25000.0, 2500.0, 1000.0)}
        assert lats[1000.0] < lats[2500.0] < lats[25000.0]


class TestC8DriverLatency:
    def test_gup_costs_more_in_kernel(self):
        tap = run_remote_write(16384, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                               strategy=Strategy.TOUCH_A_PAGE)
        ta = run_remote_write(16384, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                              strategy=Strategy.TOUCH_AHEAD)
        assert ta.stats.driver_us > tap.stats.driver_us
        # but Touch-Ahead does all the paging in kernel -> less user time
        assert ta.stats.user_us < tap.stats.user_us
        # and both are microsecond-scale (not ms)
        assert tap.stats.driver_us < 100 and ta.stats.driver_us < 100


class TestC9FirehoseCliff:
    def test_latency_jumps_past_pinnable_memory(self):
        cfg = FirehoseConfig(M_bytes=4 << 20, maxvictim_bytes=1 << 20,
                             n_nodes=2)
        node = FirehoseNode(cfg)
        buckets_in_m = cfg.M_bytes // cfg.bucket_bytes

        def avg_put(working_set_buckets, rounds=3):
            # "Tests are run long enough to reach a steady state": warm pass
            for b in range(working_set_buckets):
                node.put_latency_us(b)
            total = 0.0
            n = 0
            for _ in range(rounds):
                for b in range(working_set_buckets):
                    total += node.put_latency_us(b)
                    n += 1
            return total / n

        small = avg_put(buckets_in_m // 2)          # fits: ~pure RTT
        big = avg_put(int(buckets_in_m * 1.6))      # exceeds M+MAXVICTIM
        assert small == pytest.approx(cfg.rtt_us, rel=0.35)
        assert big > 2.0 * small                    # the Fig 2.3 cliff
