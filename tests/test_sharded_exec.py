"""Numerical equivalence of the distributed paths on 8 host devices.

The §Perf iterations changed *how* things compute (shard_map paged decode,
logical activation rules, 2-D EP); these tests run the same model under a
(2 data × 4 model) mesh and on one device and assert identical outputs.
Runs in a subprocess so the main pytest process keeps one device.
"""

import os
import subprocess
import sys

import pytest

# full model/kernel/device sweeps: minutes of work, deselected in the
# CI fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_configs
from repro.distributed.logical import logical_rules
from repro.distributed.sharding import cache_shardings, param_shardings
from repro.models.config import reduced
from repro.models.registry import model_for

# 8 kv heads / 8 q heads so heads divide model=4; pages divide data=2
cfg = reduced(all_configs()["codeqwen15_7b"], n_layers=2, n_heads=8,
              n_kv_heads=8, head_dim=16, d_model=64, kv_page_tokens=8)
model = model_for(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))
B, CTX = 4, 32
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                            cfg.vocab_size)

# ---- single-device reference --------------------------------------------
cache0 = model.init_decode_cache(cfg, B, CTX)
cache0["lengths"] = jnp.full((B,), 9, jnp.int32)   # mid-context decode
ref_logits, ref_cache = model.decode_step(params, cfg, cache0, tokens)

# ---- distributed: mesh (2 data x 4 model), shard_map paged decode --------
from repro.launch.mesh import axis_types_kwargs
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     **axis_types_kwargs(2))
rules = {"batch": "data", "heads": "model", "kv_heads": "model",
         "ff": "model"}
p_sh = param_shardings(params, mesh)
c_sh = cache_shardings(cache0, mesh, B)
with mesh, logical_rules(mesh, rules):
    fn = jax.jit(lambda p, c, t: model.decode_step(p, cfg, c, t),
                 in_shardings=(p_sh, c_sh, NamedSharding(mesh, P("data"))),
                 donate_argnums=(1,))
    dist_logits, dist_cache = fn(params, cache0, tokens)

np.testing.assert_allclose(np.asarray(dist_logits), np.asarray(ref_logits),
                           atol=2e-4, rtol=2e-3)
np.testing.assert_allclose(np.asarray(dist_cache["k_pool"]),
                           np.asarray(ref_cache["k_pool"]), atol=1e-5)
print("DECODE_DIST_OK")

# ---- distributed train step: logical rules + remat ----------------------
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import TrainConfig, make_train_step

tcfg = TrainConfig(microbatches=2, remat=True,
                   optimizer=AdamWConfig(lr=1e-3))
step = make_train_step(cfg, tcfg)
opt = adamw.init(tcfg.optimizer, params)
tk = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab_size)
lb = jnp.roll(tk, -1, axis=1)

p_ref, o_ref, m_ref = jax.jit(step)(params, opt, tk, lb)

opt_sh = adamw.AdamWState(
    step=NamedSharding(mesh, P()),
    mu=jax.tree_util.tree_map(lambda s, sh: sh, opt.mu, p_sh),
    nu=jax.tree_util.tree_map(lambda s, sh: sh, opt.nu, p_sh))
with mesh, logical_rules(mesh, rules):
    fn = jax.jit(step, in_shardings=(p_sh, opt_sh,
                                     NamedSharding(mesh, P("data", None)),
                                     NamedSharding(mesh, P("data", None))),
                 out_shardings=(p_sh, opt_sh, None))
    p_dist, o_dist, m_dist = fn(params, opt, tk, lb)

np.testing.assert_allclose(float(m_dist["loss"]), float(m_ref["loss"]),
                           atol=1e-4, rtol=1e-4)
for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                jax.tree_util.tree_leaves(p_dist)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-3)
print("TRAIN_DIST_OK")
"""


def test_distributed_paths_match_single_device():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert "DECODE_DIST_OK" in r.stdout, r.stdout[-800:] + r.stderr[-3000:]
    assert "TRAIN_DIST_OK" in r.stdout, r.stdout[-800:] + r.stderr[-3000:]
