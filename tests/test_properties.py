"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import addresses as A
from repro.core.addresses import NetlinkMessage, RAPFMessage, split_blocks
from repro.core.engine import BufferPrep, RDMAEngine
from repro.core.fault_fifo import FaultFIFO, FIFOEntry
from repro.core.pagetable import FrameAllocator, PageState, PageTable
from repro.core.resolver import Strategy


class TestAddressInvariants:
    @given(st.integers(0, 2**38), st.integers(1, 1 << 20))
    @settings(max_examples=200, deadline=None)
    def test_block_segmentation_covers_exactly(self, va, nbytes):
        """R5 segmentation: blocks tile [va, va+nbytes) exactly, 16KB-aligned."""
        blocks = split_blocks(va, nbytes)
        assert sum(n for _, n in blocks) == nbytes
        cur = va
        for bva, bn in blocks:
            assert bva == cur
            assert bn <= A.BLOCK_SIZE
            # no block crosses a 16 KB boundary
            assert (bva // A.BLOCK_SIZE) == ((bva + bn - 1) // A.BLOCK_SIZE)
            cur += bn

    @given(st.integers(0, (1 << 22) - 1), st.integers(0, (1 << 14) - 1),
           st.integers(0, (1 << 14) - 1), st.integers(0, (1 << 32) - 1),
           st.integers(0, (1 << 16) - 1), st.integers(0, 1))
    @settings(max_examples=200, deadline=None)
    def test_netlink_roundtrip(self, src, tr, seq, iova, pdid, rw):
        """Table 3.1 message encoding is lossless through the hex wire."""
        msg = NetlinkMessage(src, tr, seq, iova, pdid, rw)
        assert NetlinkMessage.decode_hex(msg.encode_hex()) == msg

    @given(st.integers(0, (1 << 16) - 1), st.integers(0, (1 << 14) - 1),
           st.integers(0, (1 << 12) - 1))
    @settings(max_examples=100, deadline=None)
    def test_rapf_roundtrip(self, pdid, tr, seq):
        msg = RAPFMessage(wired_pdid=pdid, rcved_pdid=pdid, tr_id=tr,
                          seq_num=seq)
        w0, w1 = msg.encode_words()
        dec = RAPFMessage.decode_words(w0, w1)
        assert (dec.wired_pdid, dec.tr_id, dec.seq_num) == (pdid, tr, seq)
        assert dec.opcode == A.OPCODE_RAPF

    @given(st.integers(0, (1 << 22) - 1), st.integers(0, (1 << 14) - 1),
           st.integers(0, (1 << 14) - 1), st.integers(0, (1 << 16) - 1),
           st.integers(0, (1 << 32) - 1))
    @settings(max_examples=150, deadline=None)
    def test_fifo_entry_bit_layout_roundtrip(self, src, tr, seq, pdid, iova):
        """Table 3.2: 128-bit FIFO entry packing is lossless."""
        e = FIFOEntry(src_id=src, tr_id=tr, seq_num=seq, pdid=pdid,
                      iova_field=iova)
        w = e.pack_words()
        for word in w:
            assert 0 <= word < (1 << 32)
        d = FIFOEntry.unpack_words(*w)
        assert (d.src_id, d.tr_id, d.seq_num, d.pdid, d.iova_field) == \
            (src, tr, seq, pdid, iova)


class TestFIFOInvariants:
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                    min_size=1, max_size=200))
    @settings(max_examples=100, deadline=None)
    def test_dedup_only_drops_consecutive_duplicates(self, pushes):
        """Every entry differing from its predecessor is preserved (up to
        capacity); consecutive duplicates are absorbed."""
        fifo = FaultFIFO(depth=512)
        expected = []
        last = None
        for tr, page in pushes:
            e = FIFOEntry(src_id=0, tr_id=tr, seq_num=0, pdid=1,
                          iova_field=page)
            if last is not None and last == (tr, page):
                assert not fifo.push(e)
            else:
                assert fifo.push(e)
                expected.append((tr, page))
            last = (tr, page)
        got = []
        while not fifo.empty:
            e = fifo.pop_entry()
            got.append((e.tr_id, e.iova_field))
        assert got == expected

    def test_two_read_pop_fsm_safe_order(self):
        fifo = FaultFIFO()
        e = FIFOEntry(src_id=1, tr_id=2, seq_num=3, pdid=4, iova_field=5)
        fifo.push(e)
        # reading the high half first must NOT pop
        fifo.read64(1)
        assert len(fifo) == 1
        fifo.read64(0)
        fifo.read64(1)
        assert len(fifo) == 0


class TestPageTableInvariants:
    @given(st.lists(st.sampled_from(["touch", "reclaim", "thp", "pin",
                                     "unpin"]), min_size=1, max_size=60),
           st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_frame_accounting_consistent(self, ops, seed):
        """Frames used == resident pages; no frame double-owned."""
        rng = np.random.default_rng(seed)
        alloc = FrameAllocator(total_frames=128)
        pt = PageTable(1, alloc)
        pt.mmap(0, 64 * A.PAGE_SIZE)
        for op in ops:
            vpn = int(rng.integers(0, 64))
            try:
                if op == "touch":
                    pt.touch(vpn)
                elif op == "reclaim":
                    pt.reclaim(int(rng.integers(1, 8)))
                elif op == "thp":
                    pt.khugepaged_collapse(vpn)
                elif op == "pin":
                    pt.pin(vpn * A.PAGE_SIZE, A.PAGE_SIZE)
                elif op == "unpin":
                    pt.unpin(vpn * A.PAGE_SIZE, A.PAGE_SIZE)
            except Exception:
                raise
            resident = sum(1 for e in pt.entries.values()
                           if e.state == PageState.RESIDENT)
            assert alloc.used == resident
            frames = [e.frame for e in pt.entries.values()
                      if e.state == PageState.RESIDENT]
            assert len(frames) == len(set(frames)), "double-owned frame"
            pinned = sum(1 for e in pt.entries.values() if e.pinned)
            assert pinned == pt.pinned_pages

    def test_pinned_pages_survive_thp_and_reclaim(self):
        alloc = FrameAllocator(256)
        pt = PageTable(1, alloc)
        pt.mmap(0, 32 * A.PAGE_SIZE)
        pt.pin(0, 4 * A.PAGE_SIZE)
        for v in range(4, 32):
            pt.touch(v)
        pt.khugepaged_collapse(0)
        pt.reclaim(100)
        for v in range(4):
            assert pt.is_resident(v), "pinned page evicted"


@pytest.mark.parametrize("strategy", [Strategy.TOUCH_A_PAGE,
                                      Strategy.TOUCH_AHEAD,
                                      Strategy.KERNEL_RAPF])
class TestTransferLiveness:
    """Every transfer completes, whatever the fault pattern: the timeout is
    a guaranteed backstop (the thesis' resilience argument)."""

    @given(size=st.sampled_from([16, 256, 4096, 16384, 40960, 65536]),
           src_faults=st.booleans(), dst_faults=st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_transfer_always_completes(self, strategy, size, src_faults,
                                       dst_faults):
        eng = RDMAEngine(n_nodes=1, strategy=strategy)
        pd = 1
        sp = BufferPrep.FAULTING if src_faults else BufferPrep.TOUCHED
        dp = BufferPrep.FAULTING if dst_faults else BufferPrep.TOUCHED
        eng.map_buffer(0, pd, 0x10_0000_0000, size, prep=sp)
        eng.map_buffer(0, pd, 0x20_0000_0000, size, prep=dp)
        t = eng.remote_write(pd, 0, 0x10_0000_0000, 0, 0x20_0000_0000, size)
        stats = eng.run_transfer(t)
        assert t.complete
        assert stats.latency_us > 0
        # destination pages all resident after completion
        pt = eng.nodes[0].pt(pd)
        for vpn in A.pages_spanned(0x20_0000_0000, size):
            assert pt.is_resident(vpn)

    @given(size=st.sampled_from([4096, 16384, 65536]))
    @settings(max_examples=10, deadline=None)
    def test_no_faults_no_retransmissions(self, strategy, size):
        eng = RDMAEngine(n_nodes=1, strategy=strategy)
        eng.map_buffer(0, 1, 0, size, prep=BufferPrep.TOUCHED)
        eng.map_buffer(0, 1, 0x20_0000_0000, size, prep=BufferPrep.TOUCHED)
        t = eng.remote_write(1, 0, 0, 0, 0x20_0000_0000, size)
        stats = eng.run_transfer(t)
        assert stats.timeouts == 0
        assert stats.retransmissions == 0
        assert stats.dst_faults == 0 and stats.src_faults == 0
