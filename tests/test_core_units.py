"""Unit tests: SMMU fault semantics, THP, COW, resolver costs, engine API."""

import pytest

from repro.core import addresses as A
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.engine import BufferPrep, RDMAEngine
from repro.core.fault import (FSR_MULTI, FSR_TF, SMMU, Access, Disposition,
                              FaultModel)
from repro.core.pagetable import (FrameAllocator, PageState, PageTable,
                                  PinLimitExceeded, SegmentationFault)
from repro.core.resolver import Resolver, Strategy


def make_pt(pages=16, frames=256, pin_limit=None):
    alloc = FrameAllocator(frames)
    pt = PageTable(1, alloc, pin_limit_bytes=pin_limit)
    pt.mmap(0, pages * A.PAGE_SIZE)
    return pt


class TestSMMU:
    def _smmu(self, hupcf=True, interrupts=None):
        smmu = SMMU(0, interrupt_handler=(interrupts.append
                                          if interrupts is not None else None))
        pt = make_pt()
        smmu.attach_domain(1, pt, hupcf=hupcf)
        return smmu, pt

    def test_translation_fault_records_registers(self):
        ints = []
        smmu, pt = self._smmu(interrupts=ints)
        res = smmu.translate(1, 0x5, Access.WRITE)
        assert res.disposition is Disposition.TERMINATED
        assert res.fault_recorded
        iova, wnr, is_tf = smmu.read_fault_record(1)
        assert iova == 0x5 << 12
        assert wnr == 1          # write -> destination fault
        assert is_tf
        assert ints == [1]       # CFIE raised the interrupt

    def test_multi_fault_records_only_first(self):
        ints = []
        smmu, pt = self._smmu(interrupts=ints)
        smmu.translate(1, 0x5, Access.WRITE)
        smmu.translate(1, 0x7, Access.READ)   # second, while FSR != 0
        iova, wnr, _ = smmu.read_fault_record(1)
        assert iova == 0x5 << 12              # first fault's details kept
        assert smmu.banks[1].fsr & FSR_MULTI
        assert ints == [1]                    # no second interrupt

    def test_hupcf_0_collateral_termination(self):
        """§3.2.1: without HUPCF, resident pages terminate under a fault."""
        smmu, pt = self._smmu(hupcf=False)
        pt.touch(0x3)
        assert smmu.translate(1, 0x3, Access.WRITE).disposition \
            is Disposition.OK
        smmu.translate(1, 0x9, Access.WRITE)  # open a fault
        res = smmu.translate(1, 0x3, Access.WRITE)
        assert res.disposition is Disposition.TERMINATED
        assert res.collateral

    def test_hupcf_1_processes_under_fault(self):
        smmu, pt = self._smmu(hupcf=True)
        pt.touch(0x3)
        smmu.translate(1, 0x9, Access.WRITE)  # open a fault
        res = smmu.translate(1, 0x3, Access.WRITE)
        assert res.disposition is Disposition.OK

    def test_tlb_invalidation_on_thp_collapse(self):
        smmu, pt = self._smmu()
        pt.touch(0x2)
        assert smmu.translate(1, 0x2, Access.READ).disposition is Disposition.OK
        assert smmu.translate(1, 0x2, Access.READ).tlb_hit
        pt.khugepaged_collapse(0x2)           # shoots down the TLB
        smmu.clear_fault(1)
        res = smmu.translate(1, 0x2, Access.READ)
        assert res.disposition is Disposition.TERMINATED  # faults again

    def test_stall_mode_resume(self):
        smmu = SMMU(0)
        pt = make_pt()
        smmu.attach_domain(2, pt, fault_model=FaultModel.STALL)
        res = smmu.translate(2, 0x4, Access.WRITE)
        assert res.disposition is Disposition.STALLED
        pt.touch(0x4)
        assert smmu.resume_stalled(2, retry=True) is Disposition.OK


class TestPageTable:
    def test_demand_paging_minor_fault(self):
        pt = make_pt()
        assert pt.lookup(0).state == PageState.MAPPED_NOT_RESIDENT
        major, _ = pt.touch(0)
        assert not major
        assert pt.stats.minor_faults == 1

    def test_swapped_page_major_fault(self):
        pt = make_pt()
        pt.touch(0)
        pt.reclaim(1)
        assert pt.lookup(0).state == PageState.SWAPPED
        major, _ = pt.touch(0)
        assert major
        assert pt.stats.major_faults == 1

    def test_segfault_on_unmapped(self):
        pt = make_pt(pages=4)
        with pytest.raises(SegmentationFault):
            pt.touch(100)

    def test_cow_break_allocates_new_frame(self):
        pt = make_pt()
        pt.touch(0)
        f0 = pt.entries[0].frame
        pt.fork_share([0])
        pt.touch(0, write=True)
        assert pt.entries[0].frame != f0
        assert pt.stats.cow_breaks == 1

    def test_pin_limit_enforced(self):
        pt = make_pt(pages=16, pin_limit=4 * A.PAGE_SIZE)
        pt.pin(0, 4 * A.PAGE_SIZE)
        with pytest.raises(PinLimitExceeded):
            pt.pin(8 * A.PAGE_SIZE, 4 * A.PAGE_SIZE)

    def test_get_user_pages_stops_at_unmapped(self):
        """§3.2.2.1: GUP returns only pages the application owns."""
        pt = make_pt(pages=4)
        n = pt.get_user_pages(2, 4)
        assert n == 2   # pages 2,3 mapped; 4,5 are not


class TestResolver:
    def test_touch_ahead_resolves_block(self):
        pt = make_pt()
        r = Resolver(Strategy.TOUCH_AHEAD, DEFAULT_COST_MODEL)
        res = r.resolve(pt, 0, is_dst=True, block_pages_remaining=4)
        assert res.pages_resolved == 4
        assert all(pt.is_resident(v) for v in range(4))
        assert res.kernel_us > 0 and res.user_us > 0  # RAPF via user space

    def test_kernel_rapf_no_user_time(self):
        pt = make_pt()
        r = Resolver(Strategy.KERNEL_RAPF, DEFAULT_COST_MODEL)
        res = r.resolve(pt, 0, is_dst=True, block_pages_remaining=4)
        assert res.user_us == 0.0
        assert res.rapf_from_kernel

    def test_touch_a_page_segfault_recovery(self):
        """Fig 3.2: touching a page that left the address space."""
        pt = make_pt(pages=4)
        pt.munmap(0, A.PAGE_SIZE)
        r = Resolver(Strategy.TOUCH_A_PAGE, DEFAULT_COST_MODEL)
        res = r.resolve(pt, 0, is_dst=True, block_pages_remaining=4)
        assert res.segfault_recovered
        assert res.pages_resolved == 0


class TestEngineAPI:
    def test_remote_read_is_reversed_write(self):
        """§1.3.2.2: the target's R5 converts the read into a write back."""
        eng = RDMAEngine(n_nodes=2)
        eng.map_buffer(1, 1, 0x1000_0000, 8192, prep=BufferPrep.TOUCHED)
        eng.map_buffer(0, 1, 0x2000_0000, 8192, prep=BufferPrep.FAULTING)
        t = eng.remote_read(1, target_node=1, target_va=0x1000_0000,
                            local_node=0, local_va=0x2000_0000, nbytes=8192)
        stats = eng.run_transfer(t)
        assert t.complete
        assert stats.dst_faults > 0     # local (initiator) side faulted
        for vpn in A.pages_spanned(0x2000_0000, 8192):
            assert eng.nodes[0].pt(1).is_resident(vpn)

    def test_thp_collapse_faults_pretouched_buffer(self):
        """The THP motivation: touched buffers still fault mid-run."""
        eng = RDMAEngine(n_nodes=1)
        eng.map_buffer(0, 1, 0, 16384, prep=BufferPrep.TOUCHED)
        eng.map_buffer(0, 1, 0x2000_0000, 16384, prep=BufferPrep.TOUCHED)
        # khugepaged invalidates the (touched!) destination region
        eng.nodes[0].pt(1).khugepaged_collapse(A.page_index(0x2000_0000))
        t = eng.remote_write(1, 0, 0, 0, 0x2000_0000, 16384)
        stats = eng.run_transfer(t)
        assert stats.dst_faults > 0
        assert t.complete

    def test_pinned_buffers_never_fault(self):
        eng = RDMAEngine(n_nodes=1)
        eng.map_buffer(0, 1, 0, 65536, prep=BufferPrep.PINNED)
        eng.map_buffer(0, 1, 0x2000_0000, 65536, prep=BufferPrep.PINNED)
        eng.nodes[0].pt(1).khugepaged_collapse(A.page_index(0x2000_0000))
        t = eng.remote_write(1, 0, 0, 0, 0x2000_0000, 65536)
        stats = eng.run_transfer(t)
        assert stats.dst_faults == 0 and stats.src_faults == 0
