"""Property tests: random multi-tenant traffic upholds the harness
invariants, and the vmem pager conserves frames / respects pins."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.api import BufferPrep, ServiceClass
from repro.testing import (FaultInjection, TenantSpec,
                           check_vmem_frame_conservation, check_vmem_pins,
                           soak)
from repro.vmem import HostFramePool, Pager


# --------------------------------------------------------------- strategies
tenant_specs = st.builds(
    TenantSpec,
    pd=st.just(0),                      # rewritten to a unique pd below
    service_class=st.sampled_from([None, ServiceClass.LATENCY,
                                   ServiceClass.BULK]),
    arb_weight=st.integers(1, 4),
    max_outstanding_blocks=st.sampled_from([None, 4, 8]),
    mode=st.sampled_from(["closed", "open"]),
    inflight=st.integers(1, 3),
    arrival_period_us=st.sampled_from([40.0, 200.0]),
    n_requests=st.integers(2, 5),
    size_choices=st.sampled_from([(4096,), (16384,), (4096, 65536)]),
    src_prep=st.sampled_from([BufferPrep.TOUCHED, BufferPrep.PINNED]),
    dst_prep=st.sampled_from([BufferPrep.TOUCHED, BufferPrep.FAULTING]),
    fresh_dst=st.booleans(),
)

injections = st.sampled_from([
    None,
    FaultInjection(khugepaged_period_us=400.0),
    FaultInjection(khugepaged_period_us=500.0, reclaim_period_us=700.0,
                   reclaim_pages=8),
])


class TestArbiterTrafficInvariants:
    @given(specs=st.lists(tenant_specs, min_size=1, max_size=3),
           seed=st.integers(0, 2**32 - 1), injection=injections)
    @settings(max_examples=25, deadline=None)
    def test_random_traffic_upholds_invariants(self, specs, seed, injection):
        """ANY seed, ANY tenant mix: no lost/duplicated completions, no
        pinned page reclaimed, per-domain stats sum to fabric stats,
        deficit counters inside the fairness bound."""
        specs = [
            # unique pd per tenant (one SMMU context bank each)
            type(s)(**{**s.__dict__, "pd": i + 1, "name": f"t{i + 1}"})
            for i, s in enumerate(specs)
        ]
        r = soak(seed, tenants=specs, injection=injection)
        assert r.violations == []
        for t in r.stats["tenants"]:
            assert t["completed"] == t["posted"] == \
                specs[t["pd"] - 1].n_requests

    @given(seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_quota_never_oversubscribes(self, seed):
        """With a quota of Q blocks, the arbiter never tracks more than
        Q + blocks-per-WR outstanding for the domain (post-time check +
        one in-flight overshoot)."""
        spec = TenantSpec(pd=1, mode="open", arrival_period_us=5.0,
                          n_requests=8, size_choices=(65536,),
                          dst_prep=BufferPrep.FAULTING, fresh_dst=True,
                          max_outstanding_blocks=4)
        r = soak(seed, tenants=[spec])
        assert r.violations == []
        blocks_per_wr = 65536 // 16384
        peak = max(
            (s["enqueued"] for node in r.stats["arbiter"].values()
             for k, s in node.items() if k != "total"), default=0)
        assert peak <= spec.n_requests * blocks_per_wr
        assert r.stats["tenants"][0]["completed"] == 8


class TestVmemFrameConservation:
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["access", "ensure", "pin", "unpin"]),
                  st.integers(0, 15)),
        min_size=1, max_size=60),
        n_frames=st.integers(2, 8))
    @settings(max_examples=40, deadline=None)
    def test_frame_conservation_any_op_sequence(self, ops, n_frames):
        """Random pager traffic: no frame double-owned, used == resident,
        nothing mapped sits on the free list — any seed, any sequence."""
        pool = HostFramePool(n_frames, page_elems=4)
        pager = Pager(pool)
        spaces = [pager.create_space(16, name=f"sp{i}") for i in range(2)]
        for op, vpage in ops:
            sp = spaces[vpage % 2]
            try:
                if op == "access":
                    sp.access([vpage])
                elif op == "ensure":
                    sp.ensure_resident([vpage])
                elif op == "pin":
                    sp.pin([vpage])
                elif op == "unpin":
                    sp.unpin([vpage])
            except MemoryError:
                pass    # pool exhausted with everything pinned: legal
            assert check_vmem_frame_conservation(pool) == []
            assert check_vmem_pins(pool) == []

    @given(pin_pages=st.lists(st.integers(0, 7), min_size=1, max_size=4,
                              unique=True),
           churn=st.lists(st.integers(8, 31), min_size=1, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_pinned_frames_never_evicted(self, pin_pages, churn):
        """Pin a few pages, then thrash a pool smaller than the working
        set: evictions must only ever take unpinned pages."""
        pool = HostFramePool(len(pin_pages) + 2, page_elems=4)
        pager = Pager(pool)
        sp = pager.create_space(32, name="tenant")
        sp.pin(pin_pages)
        for vpage in churn:
            try:
                sp.access([vpage])
            except MemoryError:
                pass
            for p in pin_pages:
                assert sp.page_table[p] != -1, f"pinned page {p} evicted"
            assert check_vmem_pins(pool) == []
        assert check_vmem_frame_conservation(pool) == []
        assert sp.stats.evictions > 0 or len(churn) <= 2