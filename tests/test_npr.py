"""NP-RDMA backend (repro.npr): MTT cache, DMA pool, speculation,
strategy coercion, and the unified stats surfaces."""

import dataclasses
import json

import pytest

from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy)
from repro.api.fabric import ProtocolStats
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.experiments import run_remote_write
from repro.core.node import TrIdStats
from repro.core.resolver import coerce_strategy
from repro.core.simulator import EventLoop
from repro.npr import DMAPool, MTTCache, NPRStats
from repro.testing import TenantSpec, soak
from repro.vmem.stats import PagingStats

SRC, DST, PD = 0x10_0000_0000, 0x20_0000_0000, 1


# --------------------------------------------------------------------- MTT
class TestMTTCache:
    def test_miss_fill_hit(self):
        stats = NPRStats()
        mtt = MTTCache(4, stats)
        assert mtt.lookup(1, 100) is None
        mtt.install(1, 100, frame=7)
        e = mtt.lookup(1, 100)
        assert e is not None and e.frame == 7 and not e.stale
        assert stats.mtt_fills == 1

    def test_invalidate_marks_stale_once(self):
        stats = NPRStats()
        mtt = MTTCache(4, stats)
        mtt.install(1, 100, frame=7)
        mtt.invalidate(1, 100)
        mtt.invalidate(1, 100)              # idempotent
        assert mtt.lookup(1, 100).stale
        assert stats.mtt_invalidations == 1
        # refresh clears staleness
        mtt.install(1, 100, frame=9)
        e = mtt.lookup(1, 100)
        assert e.frame == 9 and not e.stale

    def test_lru_eviction_order(self):
        stats = NPRStats()
        mtt = MTTCache(2, stats)
        mtt.install(1, 1, frame=1)
        mtt.install(1, 2, frame=2)
        mtt.lookup(1, 1)                    # 1 becomes most-recent
        mtt.install(1, 3, frame=3)          # evicts vpn 2, not vpn 1
        assert mtt.lookup(1, 2) is None
        assert mtt.lookup(1, 1) is not None
        assert stats.mtt_evictions == 1

    def test_domains_isolated(self):
        mtt = MTTCache(8, NPRStats())
        mtt.install(1, 100, frame=7)
        assert mtt.lookup(2, 100) is None


# ---------------------------------------------------------------- DMA pool
class _FakeBlock:
    def __init__(self, n_pages=4):
        self.n_pages = n_pages


class TestDMAPool:
    def _pool(self, n_frames=8, on_frames_available=None):
        loop = EventLoop()
        stats = NPRStats()
        pool = DMAPool(loop, DEFAULT_COST_MODEL, n_frames, stats,
                       on_frames_available=on_frames_available)
        pool.materialize()
        return loop, stats, pool

    def test_reserve_cancel_conserves_frames(self):
        _, _, pool = self._pool()
        b = _FakeBlock()
        assert pool.reserve(b)
        assert pool.reserve(b)              # idempotent
        assert pool.frames_accounted() == 8
        pool.cancel(b)
        assert len(pool.free) == 8

    def test_exhaustion_then_refill(self):
        loop, stats, pool = self._pool(n_frames=4)
        b1, b2 = _FakeBlock(), _FakeBlock()
        assert pool.reserve(b1)
        assert not pool.reserve(b2)         # all-or-nothing: pool dry
        assert stats.pool_reserve_failures == 1
        pool.retire(b1)                     # below watermark -> refill
        assert pool.frames_accounted() == 4
        loop.run()
        assert stats.pool_refills == 1
        assert len(pool.free) == 4
        assert pool.reserve(b2)

    def test_waiters_woken_in_fifo_order(self):
        woken = []
        loop, _, pool = self._pool(n_frames=4,
                                   on_frames_available=woken.append)
        b1, b2, b3 = _FakeBlock(), _FakeBlock(), _FakeBlock()
        assert pool.reserve(b1)
        pool.add_waiter(b2)
        pool.add_waiter(b3)
        pool.add_waiter(b2)                 # dedup
        pool.retire(b1)
        loop.run()
        assert woken == [b2, b3]

    def test_reserved_peak_tracked(self):
        _, stats, pool = self._pool(n_frames=8)
        pool.reserve(_FakeBlock())
        pool.reserve(_FakeBlock())
        assert stats.pool_reserved_peak == 8


# ------------------------------------------------------- strategy coercion
class TestStrategyCoercion:
    def test_member_passthrough(self):
        assert coerce_strategy(Strategy.NP_RDMA) is Strategy.NP_RDMA

    @pytest.mark.parametrize("spelling", ["np_rdma", "NP_RDMA", "Np_Rdma"])
    def test_string_spellings(self, spelling):
        assert coerce_strategy(spelling) is Strategy.NP_RDMA

    def test_error_names_valid_members(self):
        with pytest.raises(ValueError) as ei:
            coerce_strategy("smmu_magic")
        msg = str(ei.value)
        for member in Strategy:
            assert member.name in msg

    def test_fault_policy_coerces(self):
        assert (FaultPolicy(strategy="np_rdma").strategy
                is Strategy.NP_RDMA)

    def test_fault_policy_rejects_unknown(self):
        with pytest.raises(ValueError) as ei:
            FaultPolicy(strategy="bogus")
        assert "NP_RDMA" in str(ei.value)
        assert "TOUCH_AHEAD" in str(ei.value)

    def test_fault_policy_rejects_wrong_type(self):
        with pytest.raises(ValueError):
            FaultPolicy(strategy=3.14)


# -------------------------------------------------------------- end-to-end
def _npr_fabric(**over):
    cfg = dict(n_nodes=1,
               default_policy=FaultPolicy(strategy=Strategy.NP_RDMA))
    cfg.update(over)
    return Fabric.build(FabricConfig(**cfg))


class TestNPRDatapath:
    def test_src_fault_fixup_beats_timeout(self):
        """Source faults recover host-side in us — no 1 ms timeout."""
        npr = run_remote_write(16384, BufferPrep.FAULTING,
                               BufferPrep.TOUCHED, backend="np_rdma")
        rapf = run_remote_write(16384, BufferPrep.FAULTING,
                                BufferPrep.TOUCHED, backend="rapf")
        assert npr.stats.src_faults > 0
        assert npr.stats.timeouts == 0
        assert rapf.stats.timeouts > 0
        assert npr.latency_us < rapf.latency_us

    def test_dst_fault_abort_and_redirect(self):
        r = run_remote_write(16384, BufferPrep.TOUCHED,
                             BufferPrep.FAULTING, backend="np_rdma")
        assert r.stats.npr_aborts > 0
        assert r.stats.pool_redirect_pages > 0
        assert r.stats.timeouts == 0

    def test_mtt_warms_across_transfers(self):
        fabric = _npr_fabric()
        dom = fabric.open_domain(PD)
        src = dom.register_memory(0, SRC, 16384, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(0, DST, 16384, prep=BufferPrep.TOUCHED)
        cq = fabric.create_cq(depth=4)
        first = dom.post_write(src, dst, cq=cq).result()
        second = dom.post_write(src, dst, cq=cq).result()
        assert first.stats.mtt_misses > 0
        assert second.stats.mtt_misses == 0
        assert second.stats.mtt_hits > 0
        assert second.latency_us <= first.latency_us

    def test_bounce_mode_without_speculation(self):
        """speculation=False: every block rides the pool, no aborts."""
        r = run_remote_write(16384, BufferPrep.TOUCHED,
                             BufferPrep.FAULTING, backend="np_rdma",
                             config_overrides={"speculation": False})
        assert r.stats.npr_aborts == 0
        assert r.stats.pool_redirect_pages > 0
        assert r.stats.timeouts == 0

    def test_no_stale_completions_under_collapse(self):
        """khugepaged between writes: verification catches every stale
        MTT entry; the engine counter stays zero."""
        from repro.core import addresses as A
        fabric = _npr_fabric()
        dom = fabric.open_domain(PD)
        src = dom.register_memory(0, SRC, 65536, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(0, DST, 65536, prep=BufferPrep.TOUCHED)
        cq = fabric.create_cq(depth=4)
        pt = fabric.nodes[0].pt(PD)
        stale = 0
        for _ in range(4):
            pt.khugepaged_collapse(A.page_index(DST))
            wr = dom.post_write(src, dst, cq=cq)
            wr.result()
            stale += wr.stats.mtt_stale
        eng = fabric.protocol_stats()[0].npr
        assert stale > 0
        assert eng.stale_completions == 0
        assert eng.aborts_sent > 0

    def test_pool_frames_validated(self):
        with pytest.raises(ValueError):
            FabricConfig(n_nodes=1, dma_pool_frames=1)


# ------------------------------------------------------------ stats seams
class TestStatsSurfaces:
    def test_protocol_stats_typed_sections(self):
        """No getattr fallbacks: both sections are real dataclasses."""
        fabric = _npr_fabric()
        ps = fabric.protocol_stats()[0]
        assert isinstance(ps, ProtocolStats)
        assert isinstance(ps.tr_id, TrIdStats)
        assert isinstance(ps.npr, NPRStats)
        d = ps.as_dict()
        assert set(d) == {"tr_id", "npr", "tenancy"}
        assert d["npr"]["stale_completions"] == 0

    def test_paging_stats_merge_includes_npr_fields(self):
        a = PagingStats(mtt_hits=3, mtt_misses=2, mtt_stale=1,
                        pool_redirects=4)
        b = PagingStats(mtt_hits=1, pool_redirects=1, faults=2)
        a.merge(b)
        assert (a.mtt_hits, a.mtt_misses, a.mtt_stale,
                a.pool_redirects) == (4, 2, 1, 5)
        assert a.faults == 2
        a.reset()
        assert all(getattr(a, f.name) == f.default
                   for f in dataclasses.fields(a))

    def test_soak_npr_section_round_trips(self):
        """The deterministic soak dict carries the NPR counters and
        survives a JSON round-trip unchanged (satellite: stats seams)."""
        tenants = [TenantSpec(pd=1, strategy=Strategy.NP_RDMA,
                              mode="closed", inflight=2, n_requests=6,
                              dst_prep=BufferPrep.FAULTING)]
        a = soak(31, tenants=tenants)
        b = soak(31, tenants=tenants)
        assert a.violations == []
        assert a.json() == b.json()
        decoded = json.loads(a.json())
        assert decoded["npr"]                 # NPR nodes were active
        for node_stats in decoded["npr"].values():
            assert node_stats["stale_completions"] == 0
        # round-trip: re-encoding the decoded dict is byte-identical
        assert (json.dumps(decoded, sort_keys=True)
                == json.dumps(json.loads(b.json()), sort_keys=True))
