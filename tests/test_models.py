"""Model-substrate correctness: chunked ops vs oracles, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import mamba as mamba_mod
from repro.models.attention_ops import (flash_attention_xla, mha_reference,
                                        paged_attention_xla,
                                        ring_buffer_attention)
from repro.models.config import ModelConfig, reduced
from repro.models.registry import model_for

# full model/kernel/device sweeps: minutes of work, deselected in the
# CI fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,KVH,D", [
        (1, 16, 4, 4, 8), (2, 64, 4, 2, 16), (2, 33, 8, 1, 32),
        (1, 128, 4, 4, 64),
    ])
    def test_matches_reference_causal(self, B, S, H, KVH, D):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (B, S, H, D))
        k = rand(ks[1], (B, S, KVH, D))
        v = rand(ks[2], (B, S, KVH, D))
        ref = mha_reference(q, k, v, causal=True)
        out = flash_attention_xla(q, k, v, causal=True, q_chunk=16,
                                  kv_chunk=16)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_matches_reference_windowed(self):
        ks = jax.random.split(KEY, 3)
        B, S, H, D = 2, 96, 4, 16
        q = rand(ks[0], (B, S, H, D))
        k = rand(ks[1], (B, S, 2, D))
        v = rand(ks[2], (B, S, 2, D))
        for w in (8, 32):
            ref = mha_reference(q, k, v, causal=True, window=w)
            out = flash_attention_xla(q, k, v, causal=True, window=w,
                                      q_chunk=32, kv_chunk=16)
            np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(KEY, 3)
        B, S, H, D = 1, 40, 2, 8
        q = rand(ks[0], (B, S, H, D))
        k = rand(ks[1], (B, S, H, D))
        v = rand(ks[2], (B, S, H, D))
        ref = mha_reference(q, k, v, causal=False)
        out = flash_attention_xla(q, k, v, causal=False, q_chunk=16,
                                  kv_chunk=8)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestPagedAttention:
    def test_matches_dense_reference(self):
        ks = jax.random.split(KEY, 4)
        B, H, KVH, D, ps = 3, 8, 2, 16, 8
        ctx = 37
        max_pages = 6   # 48 slots >= 37
        P = B * max_pages
        k_pool = rand(ks[0], (P, ps, KVH, D))
        v_pool = rand(ks[1], (P, ps, KVH, D))
        q = rand(ks[2], (B, H, D))
        page_table = jnp.arange(P, dtype=jnp.int32).reshape(B, max_pages)
        lengths = jnp.array([ctx, 17, 5], jnp.int32)
        out = paged_attention_xla(q, k_pool, v_pool, page_table, lengths)
        # dense reference: unfold pools into (B, S, KVH, D)
        k_dense = k_pool.reshape(B, max_pages * ps, KVH, D)
        v_dense = v_pool.reshape(B, max_pages * ps, KVH, D)
        ref = mha_reference(q[:, None], k_dense, v_dense, causal=False,
                            lengths=lengths)[:, 0]
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_unmapped_pages_ignored(self):
        ks = jax.random.split(KEY, 3)
        B, H, D, ps = 1, 2, 8, 4
        k_pool = rand(ks[0], (4, ps, 2, D))
        v_pool = rand(ks[1], (4, ps, 2, D))
        q = rand(ks[2], (B, H, D))
        pt_full = jnp.array([[0, 1, -1, -1]], jnp.int32)
        pt_less = jnp.array([[0, 1]], jnp.int32)
        lengths = jnp.array([8], jnp.int32)
        a = paged_attention_xla(q, k_pool, v_pool, pt_full, lengths)
        b = paged_attention_xla(q, k_pool, v_pool, pt_less, lengths)
        np.testing.assert_allclose(a, b, atol=1e-6)


class TestMamba:
    def _cfg(self):
        return ModelConfig(family="hybrid", d_model=32, n_layers=1,
                           ssm_state=8, ssm_head_dim=8, ssm_expand=2,
                           ssm_conv=4, dtype="float32")

    def test_chunked_matches_recurrence(self):
        cfg = self._cfg()
        p = mamba_mod.init_mamba(KEY, cfg, jnp.float32)
        x = rand(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.5
        y_chunk = mamba_mod.apply_mamba(p, cfg, x, chunk=8)
        y_ref = mamba_mod.mamba_reference(p, cfg, x)
        np.testing.assert_allclose(y_chunk, y_ref, atol=1e-4, rtol=1e-3)

    def test_chunk_size_invariance(self):
        cfg = self._cfg()
        p = mamba_mod.init_mamba(KEY, cfg, jnp.float32)
        x = rand(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
        y8 = mamba_mod.apply_mamba(p, cfg, x, chunk=8)
        y16 = mamba_mod.apply_mamba(p, cfg, x, chunk=16)
        y32 = mamba_mod.apply_mamba(p, cfg, x, chunk=32)
        np.testing.assert_allclose(y8, y16, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(y16, y32, atol=1e-4, rtol=1e-3)


class TestDecodeConsistency:
    """prefill-free check: token-by-token decode == teacher-forced forward."""

    @pytest.mark.parametrize("arch", ["qwen3_14b", "h2o_danube_1_8b",
                                      "mixtral_8x7b", "deepseek_v3_671b",
                                      "zamba2_7b", "xlstm_125m"])
    def test_decode_matches_forward(self, arch):
        cfg = reduced(all_configs()[arch])
        m = model_for(cfg)
        params = m.init_params(cfg, KEY)
        B, S = 2, 12
        tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                    cfg.vocab_size)
        logits_tf, _ = m.forward(params, cfg, tokens)

        cache = m.init_decode_cache(cfg, B, 32)
        outs = []
        step = jax.jit(lambda p, c, t: m.decode_step(p, cfg, c, t))
        for t in range(S):
            lg, cache = step(params, cache, tokens[:, t:t + 1])
            outs.append(lg.reshape(B, -1))
        logits_dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_tf),
                                   atol=2e-3, rtol=2e-2)


class TestArchSmoke:
    """Reduced-config forward/train-step smoke per assigned arch (task f)."""

    @pytest.mark.parametrize("arch", sorted(all_configs()))
    def test_forward_shapes_and_finite(self, arch):
        cfg = reduced(all_configs()[arch])
        m = model_for(cfg)
        params = m.init_params(cfg, KEY)
        B, S = 2, 16
        tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        kw = {}
        if cfg.is_encdec:
            kw["frame_embeddings"] = rand(
                KEY, (B, cfg.max_source_positions, cfg.d_model))
        logits, aux = m.forward(params, cfg, tokens, **kw)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    @pytest.mark.parametrize("arch", sorted(all_configs()))
    def test_train_step_reduces_loss_no_nans(self, arch):
        cfg = reduced(all_configs()[arch])
        m = model_for(cfg)
        params = m.init_params(cfg, KEY)
        B, S = 2, 16
        tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        labels = jnp.roll(tokens, -1, axis=1)
        kw = {}
        if cfg.is_encdec:
            kw["frame_embeddings"] = rand(
                KEY, (B, cfg.max_source_positions, cfg.d_model))

        def loss(p):
            return m.loss_fn(p, cfg, tokens, labels, **kw)

        l0, grads = jax.jit(jax.value_and_grad(loss))(params)
        assert bool(jnp.isfinite(l0)), f"{arch}: non-finite loss"
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"
        # one SGD step lowers the loss
        lr = 0.05
        params2 = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        l1 = jax.jit(loss)(params2)
        assert float(l1) < float(l0), f"{arch}: loss did not decrease"
