"""Tests for the verbs-style async API: fabric builder, memory regions,
completion queues, per-domain fault policies, and the RAPF security checks.
"""

import pytest

from repro.api import (BufferPrep, CompletionQueue, Fabric, FabricConfig,
                       FaultPolicy, RegionError, Strategy, WCStatus,
                       WorkQueueFull, WROpcode)
from repro.core import addresses as A
from repro.core.addresses import RAPFMessage
from repro.core.fault_fifo import FaultFIFO, FIFOEntry

SRC = 0x10_0000_0000
DST = 0x20_0000_0000


def build_fabric(n_nodes=2, **kw):
    return Fabric.build(FabricConfig(n_nodes=n_nodes, **kw))


class TestFabricBuilder:
    def test_build_from_config_and_overrides(self):
        fab = Fabric.build(FabricConfig(n_nodes=3, hops=2))
        assert len(fab.nodes) == 3
        fab2 = Fabric.build(n_nodes=4)
        assert len(fab2.nodes) == 4
        with pytest.raises(TypeError):
            Fabric.build(FabricConfig(), n_nodes=4)

    def test_open_domain_twice_rejected(self):
        fab = build_fabric()
        fab.open_domain(1)
        with pytest.raises(ValueError):
            fab.open_domain(1)

    def test_context_bank_collision_rejected(self):
        """With bank_overcommit=False, pds colliding mod NUM_CONTEXT_BANKS
        would share an SMMU bank — silent cross-tenant page-table
        corruption — so open_domain refuses.  (The default overcommits
        the banks instead; see test_tenancy.py.)"""
        fab = build_fabric(bank_overcommit=False)
        fab.open_domain(1)
        with pytest.raises(ValueError, match="context bank"):
            fab.open_domain(1 + A.NUM_CONTEXT_BANKS)
        # a non-colliding pd is fine
        fab.open_domain(2)
        # and a colliding pd on a DISJOINT node set is fine too
        fab.open_domain(3, nodes=[0])
        fab.open_domain(3 + A.NUM_CONTEXT_BANKS, nodes=[1])

    def test_wait_livelock_guard(self):
        """A zero-delay self-rescheduling event cycle trips the event
        budget instead of hanging cq.wait()/wr.result() forever."""
        fab = build_fabric()
        def respawn():
            fab.loop.schedule(0.0, respawn)
        fab.loop.schedule(0.0, respawn)
        cq = fab.create_cq()
        with pytest.raises(RuntimeError, match="livelock"):
            cq.wait(1, max_events=10_000)

    def test_per_node_policy_applies(self):
        cfg = FabricConfig(
            n_nodes=2,
            default_policy=FaultPolicy(strategy=Strategy.TOUCH_AHEAD),
            node_policies={1: FaultPolicy(strategy=Strategy.TOUCH_A_PAGE)})
        fab = Fabric.build(cfg)
        assert fab.nodes[0].resolver.strategy is Strategy.TOUCH_AHEAD
        assert fab.nodes[1].resolver.strategy is Strategy.TOUCH_A_PAGE


class TestMemoryRegion:
    def test_prep_cost_accounting(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        mr = dom.register_memory(0, SRC, 65536, prep=BufferPrep.PINNED)
        assert mr.prep_cost.mmap_us > 0
        assert mr.prep_cost.prep_us > 0          # pin
        assert mr.prep_cost.release_us > 0       # unpin, accounted up front
        assert mr.prep_cost.munmap_us == 0
        cost = mr.deregister()
        assert cost.munmap_us > 0
        assert not mr.registered

    def test_uncharged_registration_is_free(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        mr = dom.register_memory(0, SRC, 65536, prep=BufferPrep.TOUCHED,
                                 charge=False)
        assert mr.prep_cost.total_us == 0
        assert mr.resident_pages() == len(mr.pages)   # still touched

    def test_post_on_deregistered_region_rejected(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 4096)
        src.deregister()
        with pytest.raises(RegionError):
            dom.post_write(src, dst, cq=fab.create_cq())

    def test_cross_domain_region_rejected(self):
        fab = build_fabric()
        dom_a = fab.open_domain(1)
        dom_b = fab.open_domain(2)
        src = dom_a.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        dst = dom_b.register_memory(1, DST, 4096)
        with pytest.raises(RegionError):
            dom_a.post_write(src, dst, cq=fab.create_cq())

    def test_out_of_bounds_work_request_rejected(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 8192, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 4096)
        with pytest.raises(RegionError):
            dom.post_write(src, dst, cq=fab.create_cq(), nbytes=8192)


class TestCompletionQueue:
    def test_poll_batches_and_wait(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=16)
        n = 5
        for i in range(n):
            src = dom.register_memory(0, SRC + i * 0x10_0000, 16384,
                                      prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(1, DST + i * 0x10_0000, 16384,
                                      prep=BufferPrep.TOUCHED)
            dom.post_write(src, dst, cq=cq)
        assert cq.poll() == []                 # nothing ran yet
        wcs = cq.wait(n)
        assert len(wcs) == n
        assert all(wc.status is WCStatus.SUCCESS for wc in wcs)
        assert cq.outstanding == 0
        assert cq.poll() == []                 # drained

    def test_poll_respects_max_entries(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=16)
        for i in range(4):
            src = dom.register_memory(0, SRC + i * 0x10_0000, 4096,
                                      prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(1, DST + i * 0x10_0000, 4096,
                                      prep=BufferPrep.TOUCHED)
            dom.post_write(src, dst, cq=cq)
        fab.progress()                         # run everything to completion
        first = cq.poll(max_entries=3)
        rest = cq.poll(max_entries=3)
        assert len(first) == 3 and len(rest) == 1

    def test_wait_deadline_returns_partial(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src = dom.register_memory(0, SRC, 65536, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 65536, prep=BufferPrep.TOUCHED)
        dom.post_write(src, dst, cq=cq)
        assert cq.wait(1, deadline_us=0.1) == []    # too early
        assert len(cq.wait(1)) == 1

    def test_backpressure_cap(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=8, max_outstanding=2)
        regions = []
        for i in range(3):
            regions.append((
                dom.register_memory(0, SRC + i * 0x10_0000, 4096,
                                    prep=BufferPrep.TOUCHED),
                dom.register_memory(1, DST + i * 0x10_0000, 4096,
                                    prep=BufferPrep.TOUCHED)))
        dom.post_write(*regions[0], cq=cq)
        dom.post_write(*regions[1], cq=cq)
        with pytest.raises(WorkQueueFull):
            dom.post_write(*regions[2], cq=cq)
        assert cq.stats.rejected_posts == 1
        cq.wait(2)                              # drain frees the slots
        dom.post_write(*regions[2], cq=cq)      # now accepted
        assert len(cq.wait(1)) == 1

    def test_cap_larger_than_depth_rejected(self):
        fab = build_fabric()
        with pytest.raises(ValueError):
            fab.create_cq(depth=4, max_outstanding=8)

    def test_queued_completions_never_exceed_depth(self):
        """A completion occupies its CQ slot until drained: posting a new
        generation of WRs against an undrained CQ hits the cap instead of
        overflowing the queue past ``depth``."""
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=2)
        regions = [
            (dom.register_memory(0, SRC + i * 0x10_0000, 4096,
                                 prep=BufferPrep.TOUCHED),
             dom.register_memory(1, DST + i * 0x10_0000, 4096,
                                 prep=BufferPrep.TOUCHED))
            for i in range(3)]
        dom.post_write(*regions[0], cq=cq)
        dom.post_write(*regions[1], cq=cq)
        fab.progress()                      # both complete, neither drained
        assert len(cq) == 2
        with pytest.raises(WorkQueueFull):  # slots still held by entries
            dom.post_write(*regions[2], cq=cq)
        assert len(cq.poll(1)) == 1         # drain one slot
        dom.post_write(*regions[2], cq=cq)  # now accepted
        fab.progress()
        assert len(cq) <= cq.depth

    def test_work_request_result_keeps_cq_entry(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src = dom.register_memory(0, SRC, 16384, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 16384)
        wr = dom.post_write(src, dst, cq=cq)
        assert not wr.done
        wc = wr.result()
        assert wr.done and wc.opcode is WROpcode.WRITE
        assert len(cq.poll(1)) == 1            # completion still queued


class TestMultiTenantFaultPolicy:
    def test_two_domains_different_policies_diverge(self):
        """Acceptance: one fabric, two domains, TOUCH_AHEAD vs KERNEL_RAPF
        — per-transfer stats diverge per the strategies' cost split."""
        fab = build_fabric()
        tenant_a = fab.open_domain(
            1, policy=FaultPolicy(strategy=Strategy.TOUCH_AHEAD))
        tenant_b = fab.open_domain(
            2, policy=FaultPolicy(strategy=Strategy.KERNEL_RAPF))
        cq = fab.create_cq(depth=8)
        wrs = {}
        for dom in (tenant_a, tenant_b):
            src = dom.register_memory(0, SRC + dom.pd * 0x100_0000, 65536,
                                      prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(1, DST + dom.pd * 0x100_0000, 65536,
                                      prep=BufferPrep.FAULTING)
            wrs[dom.pd] = dom.post_write(src, dst, cq=cq)
        assert len(cq.wait(2)) == 2
        fab.progress()                        # drain trailing library work
        st_a, st_b = wrs[1].stats, wrs[2].stats
        # both tenants faulted and recovered via RAPF
        assert st_a.dst_faults > 0 and st_b.dst_faults > 0
        assert st_a.rapf_retransmits > 0 and st_b.rapf_retransmits > 0
        # TOUCH_AHEAD pays the user-space RAPF hop (netlink + wakeup);
        # KERNEL_RAPF stays entirely in kernel space
        assert st_a.user_us > 0 and st_a.netlink_msgs > 0
        assert st_b.user_us == 0 and st_b.netlink_msgs == 0

    def test_domain_policy_overrides_fabric_default(self):
        fab = build_fabric(
            default_policy=FaultPolicy(strategy=Strategy.TOUCH_AHEAD))
        dom = fab.open_domain(
            1, policy=FaultPolicy(strategy=Strategy.TOUCH_A_PAGE))
        node = fab.nodes[0]
        assert node.resolver.strategy is Strategy.TOUCH_AHEAD
        assert node.resolver_for(1).strategy is Strategy.TOUCH_A_PAGE
        # unknown domains fall back to the node default
        assert node.resolver_for(99).strategy is Strategy.TOUCH_AHEAD

    def test_domain_reports_per_node_effective_policy(self):
        """Without an explicit domain policy, the per-node FabricConfig
        overrides govern the domain — and the domain reports them."""
        fab = build_fabric(
            default_policy=FaultPolicy(strategy=Strategy.TOUCH_AHEAD),
            node_policies={0: FaultPolicy(strategy=Strategy.TOUCH_A_PAGE)})
        dom = fab.open_domain(1)
        assert dom.policy_for(0).strategy is Strategy.TOUCH_A_PAGE
        assert dom.policy_for(1).strategy is Strategy.TOUCH_AHEAD
        assert fab.nodes[0].resolver_for(1).strategy is Strategy.TOUCH_A_PAGE
        # an explicit domain policy overrides everything, on every node
        dom2 = fab.open_domain(
            2, policy=FaultPolicy(strategy=Strategy.KERNEL_RAPF))
        assert dom2.policy_for(0).strategy is Strategy.KERNEL_RAPF
        assert dom2.policy_for(1).strategy is Strategy.KERNEL_RAPF

    def test_node_subset_domain_rejects_uncovered_node(self):
        fab = build_fabric()
        dom = fab.open_domain(1, nodes=[0])
        assert dom.nodes == [0]
        dom.register_memory(0, SRC, 4096)              # covered: fine
        with pytest.raises(RegionError):
            dom.register_memory(1, DST, 4096)          # not open there

    def test_high_pd_source_faults_resolve(self):
        """pds >= NUM_CONTEXT_BANKS share their bank index with lower pds;
        the source-fault handler must map the faulting bank back to the
        owning PDID (page tables, resolvers and pending blocks are keyed by
        pd, fault records by bank)."""
        pd = 1 + A.NUM_CONTEXT_BANKS          # bank 1, pd 17
        fab = build_fabric()
        dom = fab.open_domain(
            pd, policy=FaultPolicy(strategy=Strategy.TOUCH_A_PAGE))
        src = dom.register_memory(0, SRC, 16384)   # FAULTING source
        dst = dom.register_memory(1, DST, 16384, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        wr = dom.post_write(src, dst, cq=cq)
        wc = wr.result(deadline_us=1e5)        # would livelock unmapped
        assert wc.stats.src_faults > 0
        # the per-domain TOUCH_A_PAGE policy was honoured on the source path
        assert wc.stats.user_us > 0

    def test_per_domain_pin_limit(self):
        from repro.core.pagetable import PinLimitExceeded
        fab = build_fabric()
        dom = fab.open_domain(
            1, policy=FaultPolicy(pin_limit_bytes=4 * A.PAGE_SIZE))
        with pytest.raises(PinLimitExceeded):
            dom.register_memory(0, SRC, 8 * A.PAGE_SIZE,
                                prep=BufferPrep.PINNED)


class TestRemoteRead:
    def test_post_read_forwards_request_to_target(self):
        """§1.3.2.2: the read request is forwarded to the target node,
        whose R5 turns it into a write back to the initiator."""
        fab = build_fabric()
        dom = fab.open_domain(1)
        remote = dom.register_memory(1, SRC, 8192, prep=BufferPrep.TOUCHED)
        local = dom.register_memory(0, DST, 8192)   # faulting at initiator
        cq = fab.create_cq()
        wr = dom.post_read(remote, local, cq=cq)
        assert wr.opcode is WROpcode.READ
        wc = wr.result()
        # the data-moving transfer ran FROM the target TO the initiator
        assert wr.transfer.src_node.node_id == 1
        assert wr.transfer.dst_node.node_id == 0
        assert wc.stats.dst_faults > 0      # local (initiator) side faulted
        pt = fab.nodes[0].pt(1)
        for vpn in A.pages_spanned(DST, 8192):
            assert pt.is_resident(vpn)

    def test_misaligned_read_rejected(self):
        """post_read enforces the same equal-page-alignment precondition as
        post_write (the block machinery assumes it)."""
        fab = build_fabric()
        dom = fab.open_domain(1)
        remote = dom.register_memory(1, SRC, 8192, prep=BufferPrep.TOUCHED)
        local = dom.register_memory(0, DST + 0x800, 8192)
        with pytest.raises(AssertionError):
            dom.post_read(remote, local, cq=fab.create_cq())

    def test_oversized_read_rejected(self):
        fab = build_fabric()
        dom = fab.open_domain(1)
        remote = dom.register_memory(1, SRC, 4096, prep=BufferPrep.TOUCHED)
        local = dom.register_memory(0, DST, 4096)
        with pytest.raises(RegionError):
            dom.post_read(remote, local, cq=fab.create_cq(), nbytes=1 << 20)

    def test_read_with_offsets(self):
        """post_read mirrors post_write's sub-range offsets."""
        fab = build_fabric()
        dom = fab.open_domain(1)
        remote = dom.register_memory(1, SRC, 16384, prep=BufferPrep.TOUCHED)
        local = dom.register_memory(0, DST, 16384)
        cq = fab.create_cq()
        wr = dom.post_read(remote, local, cq=cq, nbytes=4096,
                           target_offset=8192, local_offset=8192)
        assert wr.result().nbytes == 4096
        pt = fab.nodes[0].pt(1)
        assert pt.is_resident(A.page_index(DST + 8192))
        with pytest.raises(RegionError):        # offset pushes out of bounds
            dom.post_read(remote, local, cq=cq, nbytes=16384,
                          target_offset=8192, local_offset=8192)

    def test_read_request_forwarding_costs_a_hop(self):
        """The request packet to a REMOTE target delays submission by the
        mailbox + wire cost; a loopback read pays only the mailbox cost."""
        lat = {}
        for nodes, target in ((1, 0), (2, 1)):
            fab = build_fabric(n_nodes=nodes)
            dom = fab.open_domain(1)
            remote = dom.register_memory(target, SRC, 4096,
                                         prep=BufferPrep.TOUCHED)
            local = dom.register_memory(0, DST, 4096,
                                        prep=BufferPrep.TOUCHED)
            cq = fab.create_cq()
            lat[nodes] = dom.post_read(remote, local, cq=cq).result().latency_us
        assert lat[2] > lat[1]


class TestRAPFSecurity:
    """The R5 firmware drops RAPFs whose seq_num or wired PDID mismatch."""

    def _paused_block(self):
        """Drive a transfer into PAUSED_DST and return (fabric, block)."""
        fab = build_fabric(n_nodes=1)
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(0, DST, 4096)     # will fault + NACK
        cq = fab.create_cq()
        wr = dom.post_write(src, dst, cq=cq)
        from repro.core.node import BlockState
        block = wr.transfer.blocks[0]
        # run until the NACK pauses the block (before any resolution RAPF)
        for _ in range(10_000):
            if block.state is BlockState.PAUSED_DST or wr.done:
                break
            fab.loop.step()
        assert block.state is BlockState.PAUSED_DST
        return fab, wr, block

    def test_stale_seq_num_dropped(self):
        fab, wr, block = self._paused_block()
        r5 = fab.nodes[0].r5
        bad = RAPFMessage(wired_pdid=1, rcved_pdid=1, tr_id=block.tr_id,
                          seq_num=(block.seq_num + 1) & 0xFFF)
        r5._rapf_body(bad, None)
        assert wr.stats.rapf_retransmits == 0      # dropped, no retransmit
        good = RAPFMessage(wired_pdid=1, rcved_pdid=1, tr_id=block.tr_id,
                           seq_num=block.seq_num & 0xFFF)
        r5._rapf_body(good, None)
        assert wr.stats.rapf_retransmits == 1

    def test_wired_pdid_mismatch_dropped(self):
        fab, wr, block = self._paused_block()
        r5 = fab.nodes[0].r5
        forged = RAPFMessage(wired_pdid=7, rcved_pdid=1, tr_id=block.tr_id,
                             seq_num=block.seq_num & 0xFFF)
        r5._rapf_body(forged, None)
        assert wr.stats.rapf_retransmits == 0      # wired-PDID check fired
        # the transfer still completes — via the LEGITIMATE RAPF the fault
        # resolution path sends (still in flight), never the forged one
        wc = wr.result()
        assert wc.status is WCStatus.SUCCESS
        assert wr.stats.rapf_retransmits == 1

    def test_non_rapf_opcode_ignored(self):
        from repro.core.node import BlockState
        fab, wr, block = self._paused_block()
        r5 = fab.nodes[0].r5
        msg = RAPFMessage(wired_pdid=1, rcved_pdid=1, tr_id=block.tr_id,
                          seq_num=block.seq_num & 0xFFF, opcode=1)
        r5.on_mailbox(msg, None)
        # run past the mailbox-poll delay: without the opcode guard a
        # _rapf_body would have been scheduled and fire a retransmit here
        fab.progress(until=fab.now + 10 * fab.cost.mailbox_poll_us)
        assert wr.stats.rapf_retransmits == 0
        assert block.state is BlockState.PAUSED_DST    # still paused


class TestFIFOBreakDedup:
    def test_break_dedup_allows_consecutive_duplicate(self):
        fifo = FaultFIFO()
        e = FIFOEntry(src_id=1, tr_id=2, seq_num=3, pdid=4, iova_field=5)
        assert fifo.push(e)
        assert not fifo.push(e)                    # hardware dedup
        fifo.break_dedup()                         # interleaved stream
        assert fifo.push(e)
        assert len(fifo) == 2


class TestDeprecatedShim:
    def test_rdma_engine_warns_and_delegates(self):
        from repro.core.engine import BufferPrep as ShimPrep, RDMAEngine
        assert ShimPrep is BufferPrep              # one enum, two import paths
        with pytest.warns(DeprecationWarning):
            eng = RDMAEngine(n_nodes=1, strategy=Strategy.TOUCH_AHEAD)
        eng.map_buffer(0, 1, SRC, 16384, prep=BufferPrep.TOUCHED)
        eng.map_buffer(0, 1, DST, 16384)
        t = eng.remote_write(1, 0, SRC, 0, DST, 16384)
        stats = eng.run_transfer(t)
        assert t.complete and stats.dst_faults > 0
        # the shim is a veneer: the same fabric objects underneath
        assert eng.nodes is eng.fabric.nodes
        assert eng.loop is eng.fabric.loop
