"""Fault-aware DMA arbiter: classes, DRR, deschedule-on-fault, quotas."""

import pytest

from repro.api import (BufferPrep, DomainQuotaExceeded, Fabric, FabricConfig,
                       FaultPolicy, ServiceClass, Strategy)
from repro.core.addresses import RAPFMessage
from repro.core.arbiter import ArbiterStats
from repro.core.node import BlockState
from repro.testing.invariants import check_arbiter_consistency

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
KB64 = 65536


def two_node_fabric(**over):
    return Fabric.build(FabricConfig(n_nodes=2, **over))


def post_pair(dom, fab, cq, i, size=KB64, node_src=0, node_dst=1,
              dst_prep=BufferPrep.TOUCHED, **kw):
    src = dom.register_memory(node_src, SRC + dom.pd * (1 << 32)
                              + i * (1 << 20), size, prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(node_dst, DST + dom.pd * (1 << 32)
                              + i * (1 << 20), size, prep=dst_prep)
    return dom.post_write(src, dst, cq=cq, **kw)


class TestServiceClassThreading:
    def test_domain_class_from_policy(self):
        fab = two_node_fabric()
        lat = fab.open_domain(1, policy=FaultPolicy(
            service_class=ServiceClass.LATENCY))
        bulk = fab.open_domain(2)
        assert fab.nodes[0].arbiter.class_of(1) is ServiceClass.LATENCY
        assert fab.nodes[0].arbiter.class_of(2) is ServiceClass.BULK
        assert lat.service_class is ServiceClass.LATENCY
        assert bulk.service_class is None     # unspecified -> BULK at arbiter

    def test_open_domain_override_beats_policy(self):
        fab = two_node_fabric()
        fab.open_domain(1, policy=FaultPolicy(
            service_class=ServiceClass.BULK),
            service_class=ServiceClass.LATENCY, arb_weight=4)
        assert fab.nodes[0].arbiter.class_of(1) is ServiceClass.LATENCY

    def test_per_wr_override(self):
        """A BULK domain can post one urgent LATENCY work request."""
        fab = two_node_fabric()
        dom = fab.open_domain(1)      # BULK by default
        cq = fab.create_cq()
        wr = post_pair(dom, fab, cq, 0, service_class=ServiceClass.LATENCY)
        assert wr.transfer.service_class is ServiceClass.LATENCY
        wr.result()
        assert all(b.service_class is ServiceClass.LATENCY
                   for b in wr.transfer.blocks)

    def test_default_wr_inherits_domain_class(self):
        fab = two_node_fabric()
        dom = fab.open_domain(1, service_class=ServiceClass.LATENCY)
        cq = fab.create_cq()
        wr = post_pair(dom, fab, cq, 0)
        wr.result()
        assert all(b.service_class is ServiceClass.LATENCY
                   for b in wr.transfer.blocks)


class TestDescheduleOnFault:
    def test_paused_block_yields_its_slot(self):
        """A NACKed (PAUSED_DST) block frees its PLDMA slot immediately."""
        fab = two_node_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        wr = post_pair(dom, fab, cq, 0, size=4096,
                       dst_prep=BufferPrep.FAULTING)
        block = wr.transfer.blocks[0]
        arb = fab.nodes[0].arbiter
        for _ in range(100_000):
            if block.state is BlockState.PAUSED_DST or wr.done:
                break
            fab.loop.step()
        assert block.state is BlockState.PAUSED_DST
        assert not block.holds_slot
        assert arb.in_flight == 0
        assert arb.domain_stats[1].deschedules >= 1
        wr.result()                       # RAPF requeues and completes
        assert arb.domain_stats[1].requeues >= 1
        assert arb.domain_stats[1].completed == len(wr.transfer.blocks)

    def test_late_rapf_after_timeout_requeue_is_noop(self):
        """Timeout requeues a paused block; a late RAPF landing in the
        grant-to-dispatch window must not steal the slot or double-queue
        the block (the double-dispatch race)."""
        fab = two_node_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        wr = post_pair(dom, fab, cq, 0, size=4096,
                       dst_prep=BufferPrep.FAULTING)
        block = wr.transfer.blocks[0]
        for _ in range(100_000):
            if block.state is BlockState.PAUSED_DST or wr.done:
                break
            fab.loop.step()
        assert block.state is BlockState.PAUSED_DST
        arb = fab.nodes[0].arbiter
        arb.requeue(block)                  # as _on_timeout would
        assert block.holds_slot and block.grant_pending
        in_flight = arb.in_flight
        dispatched = arb.stats.dispatched
        good = RAPFMessage(wired_pdid=1, rcved_pdid=1, tr_id=block.tr_id,
                           seq_num=block.seq_num & 0xFFF)
        fab.nodes[0].r5._rapf_body(good, None)   # late RAPF in the window
        assert arb.in_flight == in_flight        # slot not stolen
        assert arb.stats.dispatched == dispatched
        assert not block.queued                  # not double-queued
        wr.result()                              # completes exactly once
        assert cq.stats.completed == 1
        assert check_arbiter_consistency(fab) == []

    def test_storm_does_not_hold_slots_from_clean_tenant(self):
        """While one tenant's blocks sit paused, another's stream freely."""
        fab = two_node_fabric()
        storm = fab.open_domain(1)
        clean = fab.open_domain(2, service_class=ServiceClass.LATENCY)
        cq = fab.create_cq()
        storm_wrs = [post_pair(storm, fab, cq, i,
                               dst_prep=BufferPrep.FAULTING)
                     for i in range(4)]
        clean_wr = post_pair(clean, fab, cq, 0, size=4096)
        wc = clean_wr.result()
        # the clean 4 KB write completes in microseconds, long before the
        # storm's first 1 ms-scale fault recovery
        assert wc.latency_us < 200.0
        for wr in storm_wrs:
            wr.result(deadline_us=60e6)
        assert check_arbiter_consistency(fab) == []


class TestDomainQuota:
    def test_quota_backpressures_posts(self):
        fab = two_node_fabric()
        # each 64 KB WR submits 4 blocks; quota 8 admits two WRs and
        # refuses the third until completions drain the outstanding count
        dom = fab.open_domain(1, max_outstanding_blocks=8)
        cq = fab.create_cq()
        post_pair(dom, fab, cq, 0)
        post_pair(dom, fab, cq, 1)
        with pytest.raises(DomainQuotaExceeded):
            post_pair(dom, fab, cq, 2)
        arb = fab.nodes[0].arbiter
        assert arb.domain_stats[1].quota_rejections == 1
        assert cq.stats.posted == 2       # the rejected post never reserved
        # drain, then the domain may post again
        assert len(cq.wait(2)) == 2
        post_pair(dom, fab, cq, 3).result()

    def test_quota_from_policy(self):
        fab = two_node_fabric()
        dom = fab.open_domain(1, policy=FaultPolicy(
            max_outstanding_blocks=4))
        cq = fab.create_cq()
        post_pair(dom, fab, cq, 0)        # one 64 KB WR -> 4 blocks
        with pytest.raises(DomainQuotaExceeded):
            post_pair(dom, fab, cq, 1)

    def test_quota_applies_to_posted_read_bursts(self):
        """post_read counts against the quota at POST time (the blocks
        launch on the target node only after the request-packet delay, so
        submit-time accounting would let read bursts bypass backpressure)."""
        fab = two_node_fabric()
        dom = fab.open_domain(1, max_outstanding_blocks=4)
        cq = fab.create_cq(depth=64)
        remote = dom.register_memory(1, DST, KB64, prep=BufferPrep.TOUCHED)
        local = dom.register_memory(0, SRC, KB64, prep=BufferPrep.TOUCHED)
        dom.post_read(remote, local, cq=cq)       # 4 blocks posted
        with pytest.raises(DomainQuotaExceeded):
            dom.post_read(remote, local, cq=cq)   # burst, no loop progress
        assert len(cq.wait(1)) == 1
        dom.post_read(remote, local, cq=cq).result()

    def test_quota_is_per_domain(self):
        fab = two_node_fabric()
        a = fab.open_domain(1, max_outstanding_blocks=4)
        b = fab.open_domain(2)
        cq = fab.create_cq()
        post_pair(a, fab, cq, 0)
        with pytest.raises(DomainQuotaExceeded):
            post_pair(a, fab, cq, 1)
        post_pair(b, fab, cq, 0)          # other tenant unaffected
        assert len(cq.wait(2)) == 2


class TestDRRFairness:
    def test_weighted_tenant_finishes_first(self):
        """weight=3 vs weight=1 BULK tenants pushing identical streams:
        the weighted tenant gets ~3x the slot grants and finishes first."""
        fab = Fabric.build(FabricConfig(n_nodes=3))
        heavy = fab.open_domain(1, arb_weight=3)
        light = fab.open_domain(2, arb_weight=1)
        done_at = {}
        cqs = {1: fab.create_cq(depth=64), 2: fab.create_cq(depth=64)}
        for i in range(6):
            post_pair(heavy, fab, cqs[1], i, node_dst=1)
            post_pair(light, fab, cqs[2], i, node_dst=2)
        fab.progress()
        for pd, cq_ in cqs.items():
            wcs = cq_.poll(64)
            assert len(wcs) == 6
            done_at[pd] = max(wc.t_complete for wc in wcs)
        assert done_at[1] < done_at[2]
        arb = fab.nodes[0].arbiter
        assert arb.domain_stats[1].bytes_served == \
            arb.domain_stats[2].bytes_served          # all served eventually
        assert check_arbiter_consistency(fab) == []

    def test_stats_sum_to_total(self):
        fab = two_node_fabric()
        doms = [fab.open_domain(pd) for pd in (1, 2, 3)]
        cq = fab.create_cq(depth=64)
        for dom in doms:
            for i in range(3):
                post_pair(dom, fab, cq, i, dst_prep=BufferPrep.FAULTING)
        assert len(cq.wait(9, deadline_us=60e6)) == 9
        assert check_arbiter_consistency(fab) == []
        arb = fab.nodes[0].arbiter
        for field in ArbiterStats.ADDITIVE:
            assert getattr(arb.stats, field) == sum(
                getattr(s, field) for s in arb.domain_stats.values())


class TestSingleTenantUnchanged:
    def test_single_transfer_timing_matches_two_slot_window(self):
        """One tenant, one transfer: the shared 2-slot arbiter reproduces
        the seed's per-transfer window of 2 outstanding blocks."""
        fab = two_node_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        wc = post_pair(dom, fab, cq, 0).result()
        assert wc.stats.latency_us > 0
        arb = fab.nodes[0].arbiter
        assert arb.stats.dispatched == 4      # 64 KB = 4 blocks, no retries
        assert arb.stats.deschedules == 0     # clean transfer never paused
