"""Distributed substrate: checkpoints (+elastic reshard), FT control plane,
gradient compression, sharding rules, pipeline parallelism (8 host devices
in a subprocess so the main test process keeps 1 device)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import Checkpointer
from repro.distributed.compression import (compressed_bytes, int8_compress,
                                           int8_decompress, topk_compress,
                                           topk_decompress)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerDetector,
                                               plan_rescale)
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

# full model/kernel/device sweeps: minutes of work, deselected in the
# CI fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


def small_params(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jax.random.normal(k, (16, 4)),
                  "s": jnp.ones((4,))}}


class TestCheckpoint:
    def test_roundtrip_with_opt_state(self, tmp_path):
        params = small_params()
        opt = adamw.init(AdamWConfig(), params)
        ck = Checkpointer()
        ck.save(str(tmp_path), params, opt, step=7)
        p2, o2, step = ck.restore(str(tmp_path), 7, params, opt)
        assert step == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b), params, p2)

    def test_atomic_latest_and_gc(self, tmp_path):
        params = small_params()
        ck = Checkpointer()
        for s in (1, 2, 3, 4, 5):
            ck.save(str(tmp_path), params, None, step=s)
        assert ck.latest_step(str(tmp_path)) == 5
        dirs = sorted(os.listdir(tmp_path))
        assert len(dirs) == 3            # keep=3 garbage collection
        assert not any(d.endswith(".tmp") for d in dirs)

    def test_elastic_reshard_2_hosts_to_1(self, tmp_path):
        """Save from 2 hosts, restore on 1 (a host died) — DESIGN.md FT."""
        params = small_params()
        ck0 = Checkpointer(host_id=0, n_hosts=2)
        ck1 = Checkpointer(host_id=1, n_hosts=2)
        ck0.save(str(tmp_path), params, None, step=3)
        ck1.save(str(tmp_path), params, None, step=3)
        survivor = Checkpointer(host_id=0, n_hosts=1)
        p2, _, step = survivor.restore(str(tmp_path), 3, params,
                                       n_saved_hosts=2)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b), params, p2)


class TestFaultTolerance:
    def test_heartbeat_detects_silent_death(self):
        hb = HeartbeatMonitor(4, timeout=10.0)
        for t in (0.0, 5.0):
            for n in range(4):
                hb.beat(n, t)
        hb.beat(0, 12.0)
        hb.beat(1, 12.0)
        hb.beat(2, 12.0)          # node 3 silent since t=5
        dead = hb.check(16.0)
        assert dead == [3]
        assert hb.alive_nodes == [0, 1, 2]

    def test_straggler_detection(self):
        sd = StragglerDetector(4, threshold=1.5)
        for _ in range(5):
            for n in range(4):
                sd.record(n, 1.0 if n != 2 else 2.5)
        assert sd.stragglers() == [2]

    def test_rescale_plan_drops_dead_data_slice(self):
        plan = plan_rescale({"data": 16, "model": 16}, dead_nodes=[37])
        assert plan.viable
        assert plan.new_shape == (15, 16)     # one data slice lost
        assert plan.reshard_data_factor == pytest.approx(16 / 15)

    def test_rescale_multi_pod_keeps_pods_when_balanced(self):
        # one dead node per pod at the same slice offset
        plan = plan_rescale({"pod": 2, "data": 16, "model": 16},
                            dead_nodes=[0, 256])
        assert plan.new_shape == (2, 15, 16)


class TestCompression:
    def test_int8_error_feedback_converges(self):
        """Compressed-sum with error feedback tracks the true sum."""
        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (64, 64))}
        residual = None
        acc_true = jnp.zeros((64, 64))
        acc_comp = jnp.zeros((64, 64))
        for i in range(20):
            gi = {"w": g["w"] * (1 + 0.01 * i)}
            comp, residual = int8_compress(gi, residual)
            acc_comp += int8_decompress(comp)["w"]
            acc_true += gi["w"]
        err = jnp.abs(acc_comp - acc_true).max() / jnp.abs(acc_true).max()
        assert float(err) < 0.02

    def test_int8_wire_bytes_4x_smaller(self):
        g = {"w": jnp.ones((128, 128), jnp.float32)}
        comp, _ = int8_compress(g)
        assert compressed_bytes(comp.values) * 4 <= compressed_bytes(g)

    def test_topk_keeps_largest(self):
        g = {"w": jnp.asarray(np.linspace(-1, 1, 100).reshape(10, 10),
                              jnp.float32)}
        comp, res = topk_compress(g, k_fraction=0.1)
        dec = topk_decompress(comp, g)
        nz = np.nonzero(np.asarray(dec["w"]).ravel())[0]
        assert len(nz) == 10
        mags = np.abs(np.linspace(-1, 1, 100))
        assert set(nz) == set(np.argsort(-mags)[:10])


PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

from repro.launch.mesh import axis_types_kwargs
mesh = jax.make_mesh((4,), ("stage",),
                     **axis_types_kwargs(1))
L, D = 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) * 0.3

def layer_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, D))   # 6 microbatches
out = pipeline_apply(layer_fn, ws, x, mesh)

# reference: plain sequential layers
ref = x
for i in range(L):
    ref = jnp.tanh(ref @ ws[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("PIPELINE_OK")
"""


class TestPipelineParallel:
    def test_gpipe_matches_sequential(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        r = subprocess.run([sys.executable, "-c", PIPELINE_SCRIPT],
                           capture_output=True, text=True, env=env,
                           timeout=300)
        assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


DRYRUN_SMOKE = r"""
import sys
from repro.launch.dryrun import run_cell
rec = run_cell("xlstm_125m", "train_4k", multi_pod=True, save=False)
assert rec["status"] == "ok", rec.get("error")
assert rec["n_devices"] == 512
print("DRYRUN_OK", rec["per_device_bytes"])
"""


class TestDryRunMachinery:
    def test_multipod_cell_compiles_on_512_devices(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        r = subprocess.run([sys.executable, "-c", DRYRUN_SMOKE],
                           capture_output=True, text=True, env=env,
                           timeout=560)
        assert "DRYRUN_OK" in r.stdout, r.stdout[-500:] + r.stderr[-2000:]
