"""Paper Fig 4.1: remote write, all buffers pre-touched — transfer-only
latency ("Ideal") vs +pin / +touch overhead vs "Real" measurements."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write


def main() -> None:
    print("name,us_per_call,derived")
    c = DEFAULT_COST_MODEL
    ideal_16 = None
    for s in SIZES:
        r = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.TOUCHED)
        if s == 16:
            ideal_16 = r.latency_us
        emit(f"fig4.1/ideal/{s}B", r.latency_us, "transfer-only")
        emit(f"fig4.1/ideal+touch/{s}B", r.latency_us + 2 * c.touch_us(s),
             "plus touch of both buffers")
        emit(f"fig4.1/ideal+pin/{s}B",
             r.latency_us + 2 * (c.pin_us(s) + c.unpin_us(s)),
             "plus pin+unpin of both buffers")
        rp = run_remote_write(s, BufferPrep.PINNED, BufferPrep.PINNED)
        emit(f"fig4.1/real_pinned/{s}B", rp.latency_us + rp.prep_us,
             "Listing-4.2 style incl. prep")
    check("C1: ideal 16B RTT = 4 us", abs(ideal_16 - 4.0) < 0.25,
          f"measured {ideal_16:.2f}")


if __name__ == "__main__":
    main()
