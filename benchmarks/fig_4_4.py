"""Paper Fig 4.4/4.5: faults at SOURCE AND DESTINATION vs source-only.
The dst NACK gives the mechanism an explicit (RAPF) retransmission path,
so src+dst needs FEWER timeouts than src alone (Fig 4.6)."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    for strat in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD):
        for s in SIZES:
            both = run_remote_write(s, BufferPrep.FAULTING,
                                    BufferPrep.FAULTING, strategy=strat)
            emit(f"fig4.4/{strat.value}/both/{s}B", both.latency_us,
                 f"timeouts={both.stats.timeouts};"
                 f"rapf={both.stats.rapf_retransmits}")
    s = 65536
    src = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                           strategy=Strategy.TOUCH_A_PAGE)
    both = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.FAULTING,
                            strategy=Strategy.TOUCH_A_PAGE)
    check("C6: src+dst faster than src-only at 64KB (Fig 4.5)",
          both.latency_us < src.latency_us,
          f"both={both.latency_us:.0f}us src={src.latency_us:.0f}us")
    check("C6: src+dst needs fewer timeouts (Fig 4.6)",
          both.stats.timeouts < src.stats.timeouts,
          f"{both.stats.timeouts} vs {src.stats.timeouts}")


if __name__ == "__main__":
    main()
