"""DMA-arbiter QoS benchmark: fault isolation across tenants.

The thesis' mechanism ("a faulting transfer pauses without stalling the
engine", §3.2) scaled to multi-tenant service: a BULK tenant that takes a
destination fault on **every block** it sends (fresh, cold landing
buffers per request) shares one node's PLDMA with a clean LATENCY
serving tenant.  Four scenarios, one seed, all through
``repro.testing.soak``:

* **baseline** — the LATENCY tenant alone on the fabric;
* **contended** — LATENCY + fault-storming BULK, arbiter on
  (deschedule-on-fault + strict LATENCY priority + DRR);
* **firehose** — LATENCY + *clean* 64 KB BULK firehose: the blocks
  genuinely occupy PLDMA slots and wire, so arbitration (not
  deschedule-on-fault) is what protects the serving tenant;
* **firehose_prearb** — same mix in the seed regime (unbounded PLDMA
  occupancy via a slot count nothing here can exhaust): every launched
  block books the wire immediately, recreating the old head-of-line
  stall.

Claim checks: with the arbiter, the serving tenant's mean completion
latency stays within 2x its fault-free baseline (the ISSUE-3 bound), its
p99 stays well under one retransmission timeout, the pre-arbiter regime
is measurably worse, and the soak invariant checkers report zero
violations in every scenario.
"""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep, FabricConfig, ServiceClass
from repro.testing import FaultInjection, TenantSpec, soak

SEED = 2026

SERVING = TenantSpec(pd=1, name="serving",
                     service_class=ServiceClass.LATENCY,
                     mode="closed", inflight=2, n_requests=24,
                     size_choices=(4096,),
                     src_prep=BufferPrep.TOUCHED,
                     dst_prep=BufferPrep.TOUCHED)

#: every 64 KB request lands in a brand-new FAULTING region: all four
#: blocks of every transfer fault, NACK, pause and RAPF-retransmit
STORM = TenantSpec(pd=2, name="bulk-storm",
                   service_class=ServiceClass.BULK,
                   mode="closed", inflight=8, n_requests=16,
                   size_choices=(65536,),
                   dst_prep=BufferPrep.FAULTING, fresh_dst=True)

#: clean 64 KB BULK firehose: no faults, so its blocks genuinely occupy
#: PLDMA slots and wire — the regime where class priority (not
#: deschedule-on-fault) is what protects the serving tenant
FIREHOSE = TenantSpec(pd=2, name="bulk-firehose",
                      service_class=ServiceClass.BULK,
                      mode="closed", inflight=8, n_requests=16,
                      size_choices=(65536,),
                      dst_prep=BufferPrep.TOUCHED)

CHURN = FaultInjection(khugepaged_period_us=500.0)


def run_scenarios() -> dict:
    out = {}
    out["baseline"] = soak(SEED, tenants=[SERVING])
    out["contended"] = soak(SEED, tenants=[SERVING, STORM],
                            injection=CHURN)
    out["firehose"] = soak(SEED, tenants=[SERVING, FIREHOSE])
    # the seed regime: no shared-slot arbitration — every launched block
    # goes straight to the PLDMA/wire (approximated by a slot count no
    # workload here can exhaust), so the firehose books the wire ahead
    # of the serving tenant's small writes
    out["firehose_prearb"] = soak(
        SEED, tenants=[SERVING, FIREHOSE],
        config=FabricConfig(n_nodes=2, pldma_slots=512))
    return out


def main() -> None:
    print("name,us_per_call,derived")
    res = run_scenarios()
    serving = {k: r.stats["tenants"][0] for k, r in res.items()}
    base_mean = serving["baseline"]["latency_mean_us"]
    cont_mean = serving["contended"]["latency_mean_us"]
    cont_p99 = serving["contended"]["latency_p99_us"]
    fh_mean = serving["firehose"]["latency_mean_us"]
    fh_prearb_mean = serving["firehose_prearb"]["latency_mean_us"]
    storm = res["contended"].stats["tenants"][1]

    emit("arbiter/serving_baseline_mean", base_mean,
         f"n={SERVING.n_requests} 4KB writes, fabric idle")
    emit("arbiter/serving_contended_mean", cont_mean,
         f"vs {STORM.n_requests} 64KB all-blocks-faulting BULK writes")
    emit("arbiter/serving_contended_p99", cont_p99,
         f"storm dst_faults={storm['dst_faults']}")
    emit("arbiter/serving_vs_firehose_mean", fh_mean,
         "LATENCY class vs clean 64KB BULK firehose")
    emit("arbiter/serving_vs_firehose_prearb_mean", fh_prearb_mean,
         "same mix, pre-arbiter regime (unbounded PLDMA occupancy)")
    emit("arbiter/storm_mean", storm["latency_mean_us"],
         f"rapf={storm['rapf_retransmits']} timeouts={storm['timeouts']}")

    check("arbiter: fault-storming BULK tenant leaves LATENCY tenant's "
          "mean within 2x its fault-free baseline",
          cont_mean <= 2.0 * base_mean,
          f"{cont_mean:.1f}us vs 2x{base_mean:.1f}us")
    check("arbiter: contended LATENCY p99 stays under one retransmission "
          "timeout (no head-of-line 1ms stall)",
          cont_p99 < 1000.0, f"p99={cont_p99:.1f}us")
    check("arbiter: bounded-slot arbitration is load-bearing (pre-arbiter "
          "unbounded PLDMA occupancy degrades the serving tenant)",
          fh_prearb_mean > 1.5 * fh_mean,
          f"{fh_prearb_mean:.1f}us unbounded vs {fh_mean:.1f}us arbitrated")
    check("arbiter: storm tenant still makes progress (no starvation)",
          storm["completed"] == STORM.n_requests,
          f"{storm['completed']}/{STORM.n_requests}")
    for name, r in res.items():
        check(f"arbiter: soak invariants hold ({name})", r.ok,
              "; ".join(r.violations[:3]))


if __name__ == "__main__":
    main()
