"""Paper Fig 2.3 (related work): Firehose 8-byte put latency over an
increasing working set — the pinning cliff past M(+MAXVICTIM)."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.core.firehose import (FirehoseConfig, FirehoseNode,
                                 rendezvous_put_latency_us)


def main() -> None:
    print("name,us_per_call,derived")
    cfg = FirehoseConfig(M_bytes=8 << 20, maxvictim_bytes=1 << 20)
    buckets_m = cfg.M_bytes // cfg.bucket_bytes
    lat_small = lat_big = 0.0
    for frac in (0.25, 0.5, 0.75, 1.0, 1.1, 1.25, 1.5, 2.0):
        node = FirehoseNode(cfg)
        ws = int(buckets_m * frac)
        for b in range(ws):            # warm to steady state
            node.put_latency_us(b)
        total = n = 0
        for _ in range(2):
            for b in range(ws):
                total += node.put_latency_us(b)
                n += 1
        avg = total / n
        emit(f"fig2.3/firehose_ws_{frac:.2f}M", avg,
             f"hit_rate={node.hit_rate:.3f}")
        if frac == 0.5:
            lat_small = avg
        if frac == 2.0:
            lat_big = avg
    rdv = rendezvous_put_latency_us(8)
    emit("fig2.3/rendezvous_no_unpin", rendezvous_put_latency_us(8, unpin=False), "")
    emit("fig2.3/rendezvous", rdv, "")
    check("C9: Firehose latency cliff past pinnable memory M",
          lat_big > 2 * lat_small,
          f"{lat_small:.1f}us -> {lat_big:.1f}us")
    check("C9: past-M Firehose approaches Rendezvous(no-unpin)",
          lat_big > 0.4 * rendezvous_put_latency_us(8, unpin=False))


if __name__ == "__main__":
    main()
