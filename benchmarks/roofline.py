"""Roofline table from the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (written by ``repro.launch.dryrun``) and
prints, per (arch × shape) on the single-pod mesh:

    compute term   = HLO_dot_FLOPs/dev ÷ 197 TFLOP/s
    memory term    = HLO_bytes/dev     ÷ 819 GB/s
    collective term= collective B/dev  ÷ 50 GB/s/link
    dominant term, MODEL_FLOPS = 6·N·D (3·2·N·D fwd+bwd; 2·N·D inference),
    MODEL_FLOPS / (HLO_FLOPs × chips), and the bottleneck note.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

NOTES = {
    "compute_s": "compute-bound: raise MXU utilization (tiling, fewer "
                 "recompute flops)",
    "memory_s": "HBM-bound: fuse/reduce activation traffic, keep KV reads "
                "page-local",
    "collective_s": "ICI-bound: overlap collectives, shrink DP gradient "
                    "bytes (compression), re-balance TP/DP",
}


def load(mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def main() -> None:
    recs = load()
    if not recs:
        print("no dry-run artifacts found; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio,fits_hbm,note")
    for r in recs:
        t = r["roofline_terms_s"]
        dom = r["dominant_term"]
        print(f"{r['arch']},{r['shape']},{t['compute_s']:.3e},"
              f"{t['memory_s']:.3e},{t['collective_s']:.3e},{dom},"
              f"{r['model_flops_total']:.3e},{r['useful_flops_ratio']:.3f},"
              f"{r['fits_hbm']},\"{NOTES[dom]}\"")


if __name__ == "__main__":
    main()
