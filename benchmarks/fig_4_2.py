"""Paper Fig 4.2: remote write with page fault at DESTINATION — latency,
Touch-A-Page (Netlink) vs Touch-Ahead (get_user_pages)."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    ratios = {}
    for s in SIZES:
        tap = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                               strategy=Strategy.TOUCH_A_PAGE)
        ta = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                              strategy=Strategy.TOUCH_AHEAD)
        base = run_remote_write(s, BufferPrep.TOUCHED, BufferPrep.TOUCHED)
        ratios[s] = tap.latency_us / ta.latency_us
        emit(f"fig4.2/no_fault/{s}B", base.latency_us, "")
        emit(f"fig4.2/touch_a_page/{s}B", tap.latency_us,
             f"rapf={tap.stats.rapf_retransmits}")
        emit(f"fig4.2/touch_ahead/{s}B", ta.latency_us,
             f"rapf={ta.stats.rapf_retransmits};ratio={ratios[s]:.2f}")
    check("C3: dst-fault Touch-Ahead benefit ~1.7x @16KB (paper 1.7x)",
          abs(ratios[16384] - 1.7) < 0.15, f"{ratios[16384]:.2f}")
    check("C3: benefit dampened at 32KB by FIFO interleaving (paper 1.2x)",
          ratios[32768] < ratios[16384], f"{ratios[32768]:.2f}")
    check("C3: benefit ~1.2x @64KB (paper 1.2x)",
          abs(ratios[65536] - 1.2) < 0.15, f"{ratios[65536]:.2f}")


if __name__ == "__main__":
    main()
