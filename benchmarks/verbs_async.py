"""Verbs-API benchmark: async posting, batched CQ polling, multi-tenancy.

Beyond-paper scenario the redesigned API makes expressible:

* one fabric hosts TWO protection domains with different
  :class:`~repro.api.FaultPolicy` strategies (Touch-Ahead with the
  user-space RAPF hop vs the future-work Kernel-RAPF);
* each tenant posts a burst of remote writes with faulting destinations —
  ``post_write`` never blocks, so the fabric overlaps the page-fault
  handling of all transfers;
* completions are drained through the CQ-polling hot loop
  (``cq.poll(max_entries)``), the way real RDMA applications consume CQs;
* the per-CQ outstanding-work-request cap provides backpressure.
"""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy, WorkQueueFull)

SIZE = 65536
BURST = 8          # writes per tenant
POLL_BATCH = 4
POLL_INTERVAL_US = 100.0    # simulated time between CQ drains

SRC_BASE = 0x10_0000_0000
DST_BASE = 0x20_0000_0000
TENANTS = ((1, Strategy.TOUCH_AHEAD), (2, Strategy.KERNEL_RAPF))


def run_burst() -> dict:
    fabric = Fabric.build(FabricConfig(n_nodes=2))
    cq = fabric.create_cq(depth=64)
    wrs = {}
    for pd, strategy in TENANTS:
        dom = fabric.open_domain(pd, policy=FaultPolicy(strategy=strategy))
        for i in range(BURST):
            src = dom.register_memory(
                0, SRC_BASE + (pd * BURST + i) * (SIZE * 2), SIZE,
                prep=BufferPrep.TOUCHED)
            dst = dom.register_memory(
                1, DST_BASE + (pd * BURST + i) * (SIZE * 2), SIZE,
                prep=BufferPrep.FAULTING)
            wrs[dom.post_write(src, dst, cq=cq).wr_id] = (pd, strategy)
    t0 = fabric.now

    # ---- the CQ-polling hot loop: periodic batched drains ---------------
    # Poll every POLL_INTERVAL_US of simulated time (a real app polls at
    # its own cadence, not per-event), so completions accumulate between
    # drains and poll() returns true batches.
    pending = len(wrs)
    batch_sizes = []
    per_tenant_user_us = {pd: 0.0 for pd, _ in TENANTS}
    per_tenant_lat = {pd: [] for pd, _ in TENANTS}
    while pending:
        t_next = fabric.loop.peek_time()
        if t_next is None:
            break
        fabric.progress(until=max(fabric.now + POLL_INTERVAL_US, t_next))
        wcs = cq.poll(max_entries=POLL_BATCH)
        while wcs:
            batch_sizes.append(len(wcs))
            for wc in wcs:
                pd, _ = wrs[wc.wr_id]
                per_tenant_user_us[pd] += wc.stats.user_us
                per_tenant_lat[pd].append(wc.latency_us)
                pending -= 1
            wcs = cq.poll(max_entries=POLL_BATCH)
    makespan = fabric.now - t0
    return dict(makespan=makespan, batch_sizes=batch_sizes,
                user_us=per_tenant_user_us, lat=per_tenant_lat,
                cq_stats=cq.stats)


def overlap_makespans() -> tuple[float, float]:
    """Async win: SOURCE-faulting writes from BURST different tenants
    overlap their 1 ms retransmission-timeout waits; one-at-a-time
    submission pays them back-to-back.  One domain per tenant matters:
    each PDID has its own SMMU context bank, so concurrent source faults
    are recorded (and resolved) in parallel instead of serializing on one
    bank's fault record.  Returns (burst_makespan, serial_latency_sum)."""
    fabric = Fabric.build(FabricConfig(n_nodes=2))
    cq = fabric.create_cq(depth=BURST)
    t0 = fabric.now
    for i in range(BURST):
        dom = fabric.open_domain(3 + i)          # pds 1,2 used by run_burst
        src = dom.register_memory(0, SRC_BASE + i * (SIZE * 2), SIZE,
                                  prep=BufferPrep.FAULTING)
        dst = dom.register_memory(1, DST_BASE + i * (SIZE * 2), SIZE,
                                  prep=BufferPrep.TOUCHED)
        dom.post_write(src, dst, cq=cq)
    done = cq.wait(BURST, deadline_us=60e6)
    assert len(done) == BURST
    burst_makespan = fabric.now - t0

    serial = 0.0
    for _ in range(BURST):
        fabric = Fabric.build(FabricConfig(n_nodes=2))
        dom = fabric.open_domain(3)
        src = dom.register_memory(0, SRC_BASE, SIZE,
                                  prep=BufferPrep.FAULTING)
        dst = dom.register_memory(1, DST_BASE, SIZE,
                                  prep=BufferPrep.TOUCHED)
        cq = fabric.create_cq(depth=4)
        serial += dom.post_write(src, dst, cq=cq).result().latency_us
    return burst_makespan, serial


def backpressure_events(cap: int = 4) -> int:
    fabric = Fabric.build(FabricConfig(n_nodes=2))
    dom = fabric.open_domain(1)
    cq = fabric.create_cq(depth=cap)
    rejected = 0
    for i in range(cap + 3):
        src = dom.register_memory(0, SRC_BASE + i * (SIZE * 2), SIZE,
                                  prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST_BASE + i * (SIZE * 2), SIZE,
                                  prep=BufferPrep.TOUCHED)
        try:
            dom.post_write(src, dst, cq=cq)
        except WorkQueueFull:
            rejected += 1
    return rejected


def main() -> None:
    print("name,us_per_call,derived")
    r = run_burst()
    burst_makespan, serial = overlap_makespans()
    n = 2 * BURST
    emit("verbs/burst_makespan", r["makespan"],
         f"n={n} dst-faulting writes, 2 tenants")
    emit("verbs/mean_poll_batch",
         sum(r["batch_sizes"]) / max(1, len(r["batch_sizes"])),
         f"batches={r['batch_sizes']}")
    emit("verbs/srcfault_burst_makespan", burst_makespan,
         f"n={BURST} overlapped timeouts")
    emit("verbs/srcfault_serial_sum", serial, f"n={BURST} one-at-a-time")
    ta_user = r["user_us"][1]
    kr_user = r["user_us"][2]
    emit("verbs/touch_ahead_user_us", ta_user, "tenant pd=1")
    emit("verbs/kernel_rapf_user_us", kr_user, "tenant pd=2")
    rejected = backpressure_events()

    check("verbs: batched cq.poll drains every completion",
          sum(r["batch_sizes"]) == n and r["cq_stats"].completed == n,
          f"{sum(r['batch_sizes'])}/{n} in {len(r['batch_sizes'])} batches")
    check("verbs: some poll batch carries >1 completion (batching works)",
          max(r["batch_sizes"], default=0) > 1,
          f"max batch={max(r['batch_sizes'], default=0)}")
    check("verbs: async burst overlaps timeout waits "
          "(src-faulting makespan << serial sum)",
          burst_makespan < 0.5 * serial,
          f"{burst_makespan:.0f}us vs {serial:.0f}us serial")
    check("verbs: per-domain policies diverge on one fabric "
          "(Kernel-RAPF needs no user-space hop)",
          kr_user == 0.0 and ta_user > 0.0,
          f"user_us {ta_user:.1f} vs {kr_user:.1f}")
    check("verbs: CQ backpressure rejects posts beyond the cap",
          rejected == 3, f"{rejected} rejected")


if __name__ == "__main__":
    main()
