"""Paper Fig 4.7: driver (kernel-space) latency per transfer —
interrupt handler + tasklets; Touch-Ahead moves the paging into the
kernel so its driver time exceeds Touch-A-Page's."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    for where, src, dst in (
            ("dst", BufferPrep.TOUCHED, BufferPrep.FAULTING),
            ("src", BufferPrep.FAULTING, BufferPrep.TOUCHED),
            ("both", BufferPrep.FAULTING, BufferPrep.FAULTING)):
        for s in SIZES:
            tap = run_remote_write(s, src, dst,
                                   strategy=Strategy.TOUCH_A_PAGE)
            ta = run_remote_write(s, src, dst, strategy=Strategy.TOUCH_AHEAD)
            emit(f"fig4.7/{where}/touch_a_page/{s}B", tap.stats.driver_us,
                 f"user_us={tap.stats.user_us:.1f}")
            emit(f"fig4.7/{where}/touch_ahead/{s}B", ta.stats.driver_us,
                 f"user_us={ta.stats.user_us:.1f}")
    tap = run_remote_write(16384, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                           strategy=Strategy.TOUCH_A_PAGE)
    ta = run_remote_write(16384, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                          strategy=Strategy.TOUCH_AHEAD)
    check("C8: GUP (Touch-Ahead) costs more driver time, less user time",
          ta.stats.driver_us > tap.stats.driver_us
          and ta.stats.user_us < tap.stats.user_us,
          f"driver {ta.stats.driver_us:.1f} vs {tap.stats.driver_us:.1f}")


if __name__ == "__main__":
    main()
