"""vmem over the fabric: KV frames and tensor pages paged in remotely.

The unified ``repro.vmem`` pager with its ``RemoteFramePool`` backend —
every page-in is a verbs ``post_read`` against a remote node, completing
on a real CQ, with the destination faults of the FAULTING landing buffer
resolved by the thesis mechanism (RAPF retransmits surfaced in
``PagingStats``).  Two scenarios:

* a ``PagedKVManager`` whose spilled sequences fault their KV frames
  back in over the fabric (the multi-node paged-serving precursor);
* a ``PagedTensorStore`` streaming tensor pages from remote memory under
  each resolution strategy.
"""

from __future__ import annotations

from repro.api import FaultPolicy, Strategy
from repro.memory.kv_cache import PagedKVManager
from repro.memory.paged_store import PagedTensorStore
from repro.vmem import FrameIdPool, Pager, RemoteFramePool

from benchmarks.common import check, emit


def _kv_remote(strategy: Strategy) -> tuple:
    """Spill a sequence, fault its KV frames back in over the fabric."""
    pool = RemoteFramePool.build(n_frames=8, page_elems=0, n_pages=16,
                                 local=FrameIdPool(8))
    policy = FaultPolicy(strategy, lookahead=4)
    kv = PagedKVManager(n_frames=8, page_tokens=4, max_pages_per_seq=8,
                        policy=policy, pool=pool)
    kv.add_sequence(1)
    kv.append_tokens(1, 32)                  # seq 1 fills the pool
    kv.add_sequence(2)
    kv.append_tokens(2, 16, spill_candidates=[1])   # spills 4 of seq 1
    n = kv.ensure_resident(1, spill_candidates=[2])  # remote fault-back-in
    return kv.stats, pool, n


def _store_remote(strategy: Strategy, n_pages: int = 32) -> tuple:
    pool = RemoteFramePool.build(n_frames=8, page_elems=64, n_pages=n_pages)
    store = PagedTensorStore(64, 8, n_pages, policy=FaultPolicy(
        strategy, lookahead=4), pool=pool)
    for v in range(n_pages):
        store.write_host(v, [float(v)] * 64)
    for v in range(n_pages):                 # sequential remote stream
        store.access([v])
    return store.stats, pool


def main() -> None:
    kv_us = {}
    for strategy in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD):
        stats, pool, n = _kv_remote(strategy)
        kv_us[strategy] = stats.simulated_us
        emit(f"kv_remote_fault_back_{strategy.value}",
             stats.simulated_us / max(1, n),
             f"pages={n} reads={stats.remote_reads} "
             f"rapf={stats.rapf_retransmits} "
             f"dst_faults={stats.remote_dst_faults}")
        wcs = pool.cq.poll(max_entries=64)
        check(f"KV remote page-ins complete on the CQ ({strategy.value})",
              len(wcs) + len(pool.completions) == stats.remote_reads
              and stats.remote_reads > 0,
              f"{len(wcs)} polled of {stats.remote_reads} reads")
    check("KV fault-back-in: Touch-Ahead beats Touch-A-Page over the fabric",
          kv_us[Strategy.TOUCH_AHEAD] < kv_us[Strategy.TOUCH_A_PAGE],
          f"{kv_us[Strategy.TOUCH_AHEAD]:.1f} vs "
          f"{kv_us[Strategy.TOUCH_A_PAGE]:.1f} us")

    st = {}
    for strategy in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD,
                     Strategy.STREAM):
        stats, pool = _store_remote(strategy)
        st[strategy] = stats
        emit(f"store_remote_stream_{strategy.value}",
             stats.simulated_us / max(1, stats.pages_in),
             f"pages_in={stats.pages_in} reads={stats.remote_reads} "
             f"rapf={stats.rapf_retransmits} "
             f"prefetch_hits={stats.prefetch_hits}")
    check("remote stream: RAPF retransmits surfaced in PagingStats",
          all(s.rapf_retransmits > 0 for s in st.values()),
          "cold FAULTING landing pages retransmit after fault handling")
    check("remote stream: block strategies beat Touch-A-Page",
          st[Strategy.TOUCH_AHEAD].simulated_us
          < st[Strategy.TOUCH_A_PAGE].simulated_us,
          f"{st[Strategy.TOUCH_AHEAD].simulated_us:.1f} vs "
          f"{st[Strategy.TOUCH_A_PAGE].simulated_us:.1f} us")


if __name__ == "__main__":
    main()
