"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--json PATH]`` prints every
table as ``name,us_per_call,derived`` CSV plus claim checks (DESIGN.md §1
C1-C9), exiting non-zero if any claim check fails.  ``--json PATH``
additionally writes machine-readable ``{name: us_per_call}`` results
(the BENCH_*.json perf trajectory).

``--compare OLD.json NEW.json`` runs no benchmarks: it prints a per-key
delta table between two BENCH json files and exits non-zero if any
throughput key (``*events_per_sec*``) regressed by more than
``REGRESSION_PCT`` — the CI gate between the latest committed BENCH and
the one the current commit just produced.
"""

from __future__ import annotations

import argparse
import json
import sys

#: an events/sec key may drop at most this much vs the old BENCH before
#: the compare gate fails (CI runners are noisy; a real hot-path
#: regression shows up far past this)
REGRESSION_PCT = 20.0

from benchmarks import (arbiter_qos, chaos, fig_2_3_firehose, fig_4_1,
                        fig_4_2, fig_4_3, fig_4_4, fig_4_6, fig_4_7,
                        net_congestion, npr_compare, scale_soak, table_4_1,
                        tenant_scale, thp_study, timeout_sweep, verbs_async,
                        vmem_remote)
from benchmarks.common import (add_backend_arg, apply_backend, summary,
                               write_json)

MODULES = (
    ("Table 4.1 (OS-call overheads)", table_4_1),
    ("Fig 4.1 (pre-touched transfer latency)", fig_4_1),
    ("Fig 4.2 (fault at destination)", fig_4_2),
    ("Fig 4.3 (fault at source)", fig_4_3),
    ("Fig 4.4/4.5 (faults at both)", fig_4_4),
    ("Fig 4.6 (timeout counts)", fig_4_6),
    ("Fig 4.7 (driver latency)", fig_4_7),
    ("Timeout sweep + beyond-paper resolvers", timeout_sweep),
    ("THP study (§3.1.2.3 motivation)", thp_study),
    ("Fig 2.3 (Firehose working-set cliff)", fig_2_3_firehose),
    ("Verbs API (async burst, batched CQ polling, multi-tenant)",
     verbs_async),
    ("vmem over the fabric (remote KV/tensor page-ins)", vmem_remote),
    ("DMA-arbiter QoS (multi-tenant fault isolation)", arbiter_qos),
    ("Interconnect topology (routed control packets, torus congestion)",
     net_congestion),
    ("NP-RDMA backend head-to-head (MTT speculation vs RAPF vs pinning)",
     npr_compare),
    ("Scale soak (64-128 nodes, 1M blocks, tr_id wraparound)", scale_soak),
    ("Tenancy control plane (10k tenants, bank-steal crossover, GOLD "
     "isolation)", tenant_scale),
    ("Crash-fault chaos (seeded crash storms, recovery latency, pager "
     "failover)", chaos),
)


def compare(old_path: str, new_path: str) -> int:
    """Per-key delta table between two BENCH json files.

    Returns the number of throughput regressions: ``*events_per_sec*``
    keys whose new value fell more than ``REGRESSION_PCT`` below the
    old one.  Keys only present on one side are listed informationally
    (tiers come and go); non-throughput keys are shown but never gate —
    most are virtual-time or count measurements whose changes are
    deliberate and caught by the claim checks instead.
    """
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    regressions = 0
    print(f"key,old,new,delta_pct   ({old_path} -> {new_path})")
    for key in sorted(old.keys() | new.keys()):
        if key not in old:
            print(f"{key},-,{new[key]},ADDED")
            continue
        if key not in new:
            print(f"{key},{old[key]},-,REMOVED")
            continue
        o, n = old[key], new[key]
        delta = (n - o) / o * 100.0 if o else 0.0
        flag = ""
        if "events_per_sec" in key and delta < -REGRESSION_PCT:
            flag = f"  REGRESSION (>{REGRESSION_PCT:.0f}% slower)"
            regressions += 1
        print(f"{key},{o},{n},{delta:+.1f}%{flag}")
    if regressions:
        print(f"# {regressions} throughput regression(s) beyond "
              f"{REGRESSION_PCT:.0f}%")
    else:
        print("# no throughput regressions")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write {name: us_per_call} results as JSON")
    ap.add_argument("--compare", nargs=2, metavar=("OLD.json", "NEW.json"),
                    default=None,
                    help="compare two BENCH json files instead of running "
                         "benchmarks; exit non-zero on a >"
                         f"{REGRESSION_PCT:.0f}%% events/sec regression")
    add_backend_arg(ap)
    args = ap.parse_args()
    if args.compare:
        sys.exit(1 if compare(*args.compare) else 0)
    apply_backend(args.backend)
    for title, mod in MODULES:
        print(f"\n### {title}")
        mod.main()
    print()
    fails = summary()
    if args.json:
        write_json(args.json)
        print(f"# wrote JSON results to {args.json}")
    if fails:
        sys.exit(1)


if __name__ == "__main__":
    main()
