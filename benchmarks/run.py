"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--json PATH]`` prints every
table as ``name,us_per_call,derived`` CSV plus claim checks (DESIGN.md §1
C1-C9), exiting non-zero if any claim check fails.  ``--json PATH``
additionally writes machine-readable ``{name: us_per_call}`` results
(the BENCH_*.json perf trajectory).
"""

from __future__ import annotations

import argparse
import sys

from benchmarks import (arbiter_qos, chaos, fig_2_3_firehose, fig_4_1,
                        fig_4_2, fig_4_3, fig_4_4, fig_4_6, fig_4_7,
                        net_congestion, npr_compare, scale_soak, table_4_1,
                        tenant_scale, thp_study, timeout_sweep, verbs_async,
                        vmem_remote)
from benchmarks.common import (add_backend_arg, apply_backend, summary,
                               write_json)

MODULES = (
    ("Table 4.1 (OS-call overheads)", table_4_1),
    ("Fig 4.1 (pre-touched transfer latency)", fig_4_1),
    ("Fig 4.2 (fault at destination)", fig_4_2),
    ("Fig 4.3 (fault at source)", fig_4_3),
    ("Fig 4.4/4.5 (faults at both)", fig_4_4),
    ("Fig 4.6 (timeout counts)", fig_4_6),
    ("Fig 4.7 (driver latency)", fig_4_7),
    ("Timeout sweep + beyond-paper resolvers", timeout_sweep),
    ("THP study (§3.1.2.3 motivation)", thp_study),
    ("Fig 2.3 (Firehose working-set cliff)", fig_2_3_firehose),
    ("Verbs API (async burst, batched CQ polling, multi-tenant)",
     verbs_async),
    ("vmem over the fabric (remote KV/tensor page-ins)", vmem_remote),
    ("DMA-arbiter QoS (multi-tenant fault isolation)", arbiter_qos),
    ("Interconnect topology (routed control packets, torus congestion)",
     net_congestion),
    ("NP-RDMA backend head-to-head (MTT speculation vs RAPF vs pinning)",
     npr_compare),
    ("Scale soak (64-128 nodes, 1M blocks, tr_id wraparound)", scale_soak),
    ("Tenancy control plane (10k tenants, bank-steal crossover, GOLD "
     "isolation)", tenant_scale),
    ("Crash-fault chaos (seeded crash storms, recovery latency, pager "
     "failover)", chaos),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write {name: us_per_call} results as JSON")
    add_backend_arg(ap)
    args = ap.parse_args()
    apply_backend(args.backend)
    for title, mod in MODULES:
        print(f"\n### {title}")
        mod.main()
    print()
    fails = summary()
    if args.json:
        write_json(args.json)
        print(f"# wrote JSON results to {args.json}")
    if fails:
        sys.exit(1)


if __name__ == "__main__":
    main()
