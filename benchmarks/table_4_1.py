"""Paper Table 4.1: overhead of mmap/munmap/pin/unpin/touch per buffer."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.core.costmodel import DEFAULT_COST_MODEL, TABLE_4_1, TABLE_4_1_SIZES


def main() -> None:
    c = DEFAULT_COST_MODEL
    print("name,us_per_call,derived")
    ops = {"mmap": c.mmap_us, "munmap": c.munmap_us, "pin": c.pin_us,
           "unpin": c.unpin_us, "touch": c.touch_us}
    exact = True
    for op, fn in ops.items():
        for i, size in enumerate(TABLE_4_1_SIZES):
            v = fn(size)
            emit(f"table4.1/{op}/{size}B", v, f"paper={TABLE_4_1[op][i]}")
            exact &= abs(v - TABLE_4_1[op][i]) < 1e-9
    check("C2: Table 4.1 reproduced exactly (calibration table)", exact)
    check("C2: pin cost grows with pages",
          c.pin_us(65536) > c.pin_us(16384) > c.pin_us(4096))


if __name__ == "__main__":
    main()
