"""Million-block, 64-128-node soak tier: tr_ID wraparound at scale.

The wire protocol's 14-bit ``tr_ID``/``seq_num`` fields (Table 3.2) make
ID reuse a *protocol property*: any node that launches 2^14 blocks must
recycle.  Every smaller tier in this suite stops well short of one wrap,
so the free-list allocator, the generation-tagged RAPF matching and the
O(1) fault lookups are proven here, in the regime the ROADMAP's
"millions of users" north star actually lives in:

* **64-node TORUS_2D, >= 1M blocks** — one ring tenant per node plus a
  hot pair on node 0 sized to wrap its tr_ID space at least twice, with
  a faulting tenant whose NACK/RAPF recovery spans the wrap boundaries.
  Zero invariant violations required: WR conservation, per-link packet
  conservation, arbiter accounting, tr_ID free-list/index consistency.
* **128-node DRAGONFLY** — topology breadth at reduced block count.
* **1024-node TORUS_2D** — the sharded-executor tier: run once on the
  single global wheel and once under ``FabricConfig(shards=32)``, and
  require the two stats payloads byte-identical (the
  :mod:`repro.core.shards` conservative-lookahead merge contract, proven
  at the fabric size it exists for).

Wall time and events/sec are emitted into the BENCH json trajectory, and
an events/sec floor turns harness slowdowns into CI failures.  Tune with
``--blocks`` / ``--quick`` when iterating locally; CI runs the defaults.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import check, emit
from repro.api import FabricConfig
from repro.core.addresses import TR_ID_SPACE
from repro.testing import scale_mix, soak

SEED = 2026

#: events/sec floor for the 64-node tier: the reference container
#: sustains ~3x this (≈45 K/s); the slack absorbs slower CI runners, so
#: tripping the floor means an O(pending)-style scan crept back into the
#: per-event hot path rather than machine noise
EVENTS_PER_SEC_FLOOR = 15_000.0


def run_tier(n_nodes: int, topology: str, dims: tuple, total_blocks: int,
             hot_blocks: int, seed: int = SEED, shards: int = 1):
    specs = scale_mix(n_nodes, total_blocks=total_blocks,
                      hot_blocks=hot_blocks)
    config = FabricConfig(n_nodes=n_nodes, topology=topology, dims=dims,
                          frames_per_node=1 << 16, shards=shards)
    t0 = time.perf_counter()
    result = soak(seed, tenants=specs, config=config,
                  max_events=400_000_000)
    wall = time.perf_counter() - t0
    return result, wall


def report(tag: str, result, wall: float) -> dict:
    launched = sum(s.tr_id.allocated for s in
                   result.fabric.protocol_stats().values())
    events = result.stats["events"]
    eps = events / wall if wall > 0 else 0.0
    emit(f"scale/{tag}_blocks_launched", launched, "tr_id allocations")
    emit(f"scale/{tag}_events", events, "loop events")
    emit(f"scale/{tag}_wall_s", round(wall, 3), "host seconds")
    emit(f"scale/{tag}_events_per_sec", round(eps, 1), "host throughput")
    emit(f"scale/{tag}_makespan_us", result.stats["makespan_us"],
         "virtual time")
    return {"launched": launched, "events": events, "eps": eps}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, default=1_000_000,
                    help="total 16 KB blocks for the 64-node tier")
    ap.add_argument("--quick", action="store_true",
                    help="small local iteration sizes (NOT the CI tier)")
    args, _ = ap.parse_known_args()
    blocks_64 = 120_000 if args.quick else args.blocks
    hot_64 = (TR_ID_SPACE // 4 if args.quick
              else 2 * TR_ID_SPACE + 4096)

    print("name,value,derived")

    # ------------------- 64-node torus, >= 1M blocks, >= 2 wraps ---------
    r64, wall64 = run_tier(64, "torus_2d", (8, 8), blocks_64, hot_64)
    m64 = report("64n_torus", r64, wall64)
    hot = r64.fabric.protocol_stats()[0].tr_id
    check("scale: 64-node torus soak completes with ZERO invariant "
          "violations (WR + per-link packet conservation, arbiter, "
          "tr_id lifecycle)", r64.ok, "; ".join(r64.violations[:3]))
    if not args.quick:
        check("scale: >= 1M blocks launched across the 64-node fabric",
              m64["launched"] >= 1_000_000, f"{m64['launched']}")
        check("scale: hot node crossed >= 2 tr_id wraps (recycled-ID "
              "regime, Table 3.2)", hot.wraps >= 2,
              f"wraps={hot.wraps} allocated={hot.allocated}")
        check("scale: recycled IDs actually served launches",
              hot.recycled > 0, f"recycled={hot.recycled}")
        check("scale: fault recovery (RAPF) active across the wrap",
              any(t["rapf_retransmits"] > 0
                  for t in r64.stats["tenants"]), "")
        check(f"scale: >= {EVENTS_PER_SEC_FLOOR:.0f} events/sec "
              f"(hot-path regression floor)",
              m64["eps"] >= EVENTS_PER_SEC_FLOOR, f"{m64['eps']:.0f}/s")

    # ------------------- 128-node dragonfly breadth ----------------------
    blocks_128 = 40_000 if args.quick else 120_000
    r128, wall128 = run_tier(128, "dragonfly", (8, 16), blocks_128,
                             hot_blocks=TR_ID_SPACE // 4)
    report("128n_dragonfly", r128, wall128)
    check("scale: 128-node dragonfly soak holds every invariant",
          r128.ok, "; ".join(r128.violations[:3]))

    # ------------------- 1024-node torus (the sharded-executor tier) -----
    # This tier is what caught the VA-window overflow: tenant pds above
    # 223 used to push fault IOVAs past the FIFO's 28-bit field,
    # livelocking every faulting tenant (see repro.testing.traffic
    # VA_SLOTS).  It runs twice — single wheel, then 32 per-node shards
    # merged under conservative lookahead — and the two runs must be
    # byte-identical (the repro.core.shards contract, at target scale).
    blocks_1024 = 20_000 if args.quick else 200_000
    r1k, wall1k = run_tier(1024, "torus_2d", (32, 32), blocks_1024,
                           hot_blocks=TR_ID_SPACE // 4)
    report("1024n_torus", r1k, wall1k)
    check("scale: 1024-node torus soak holds every invariant",
          r1k.ok, "; ".join(r1k.violations[:3]))
    r1ks, wall1ks = run_tier(1024, "torus_2d", (32, 32), blocks_1024,
                             hot_blocks=TR_ID_SPACE // 4, shards=32)
    report("1024n_torus_sh32", r1ks, wall1ks)
    check("scale: sharded (32-way) 1024-node run is byte-identical to "
          "the single-wheel run", r1ks.json() == r1k.json(),
          f"events {r1ks.stats['events']} vs {r1k.stats['events']}")


if __name__ == "__main__":
    main()
