"""Paper Fig 4.6: timeout counts, src-only vs src+dst (the mechanism's
explicit-retransmit advantage), across the size sweep."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    ok = True
    for s in SIZES:
        src = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                               strategy=Strategy.TOUCH_A_PAGE)
        both = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.FAULTING,
                                strategy=Strategy.TOUCH_A_PAGE)
        emit(f"fig4.6/timeouts_src/{s}B", src.stats.timeouts, "count")
        emit(f"fig4.6/timeouts_both/{s}B", both.stats.timeouts, "count")
        if s >= 16384:
            ok &= both.stats.timeouts < src.stats.timeouts
    check("C6: fewer timeouts with faults on both sides (>=16KB)", ok)


if __name__ == "__main__":
    main()
