"""Crash-fault chaos tier: seeded crash storms, recovery latency bounds.

The thesis' datapath assumes both endpoints stay alive; this tier proves
the machine-failure model wrapped around it.  Three claims:

* **zero loss** — under a seeded storm of node crashes and link flaps on
  a routed torus, every posted work request completes *exactly once*
  (with an error status when a dead machine was involved) and every
  fabric invariant holds: WR conservation, per-link packet conservation
  across down/up transitions, arbiter accounting, tr_ID lease
  reclamation;
* **bounded recovery** — a survivor talking to a crashed peer detects
  the death and errors out within the dead-round budget
  (``crash_detect_retries`` timeout rounds), never retransmitting
  forever;
* **pager failover** — a :class:`RemoteFramePool` with a replica serves
  the page-in that found its primary dead from the replica, read-your-
  writes intact, within a bounded multiple of a warm page-in.

Determinism: every schedule is fixed virtual timestamps, so each seeded
storm replays byte-identically (checked across two runs per seed).
``--quick`` shrinks the storm for local iteration; CI's fast job runs
``--quick``, the full job runs the defaults.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import check, emit
from repro.api import (BufferPrep, Fabric, FabricConfig, WCStatus)
from repro.testing import FaultInjection, TenantSpec, soak
from repro.vmem.remote import RemoteFramePool

SEEDS = (11, 42, 2026)
SRC = 0x10_0000_0000
DST = 0x20_0000_0000


# ------------------------------------------------------------- crash storm
def storm_tenants(n_requests: int) -> list[TenantSpec]:
    """Six tenants on an 8-node torus, arranged so the scheduled crash
    of node 2 hits a posting node, a destination node, and bystanders."""
    lay = [(1, 0, 1), (2, 2, 3), (3, 3, 2), (4, 4, 5), (5, 5, 6),
           (6, 7, 0)]
    return [TenantSpec(pd=pd, name=f"t{s}{d}", mode="closed", inflight=2,
                       n_requests=n_requests, src_node=s, dst_node=d,
                       dst_prep=(BufferPrep.FAULTING if pd % 2 == 0
                                 else BufferPrep.TOUCHED),
                       fresh_dst=(pd % 2 == 0))
            for pd, s, d in lay]


def storm_injection(crash_at: float) -> FaultInjection:
    """Storm schedule scaled to the run length: the node-2 crash lands
    at ``crash_at`` (mid-run, so work is genuinely in flight), with two
    link flaps bracketing it."""
    return FaultInjection(
        khugepaged_period_us=400.0, reclaim_period_us=600.0,
        crashes=((crash_at, 2),),
        link_flaps=((crash_at * 0.3, crash_at * 0.9, 0, 1),
                    (crash_at * 0.6, crash_at * 1.7, 4, 5)))


def run_storm(n_requests: int, crash_at: float) -> None:
    config = FabricConfig(n_nodes=8, topology="torus_2d")
    inj = storm_injection(crash_at)
    t0 = time.perf_counter()
    results = []
    for seed in SEEDS:
        a = soak(seed, tenants=storm_tenants(n_requests), config=config,
                 injection=inj)
        b = soak(seed, tenants=storm_tenants(n_requests), config=config,
                 injection=inj)
        results.append((seed, a, a.json() == b.json()))
    wall = time.perf_counter() - t0

    emit("chaos/storm_wall_s", round(wall, 3),
         f"{2 * len(SEEDS)} seeded soaks")
    total_posted = total_completed = total_errors = 0
    all_ok, all_identical, any_aborted = True, True, False
    for seed, res, identical in results:
        all_ok &= res.ok
        all_identical &= identical
        for t in res.stats["tenants"]:
            total_posted += t["posted"]
            total_completed += t["completed"]
            total_errors += t["errors"]
            any_aborted |= t["aborted"]
    emit("chaos/storm_posted", total_posted, "WRs across seeds")
    emit("chaos/storm_errors", total_errors, "error completions")
    check("chaos: crash-storm soaks hold EVERY invariant (WR + link "
          "conservation, arbiter, tr_id lease, crash consistency)",
          all_ok, "; ".join(results[0][1].violations[:3]))
    check("chaos: zero WR loss — every posted request completed exactly "
          "once", total_completed == total_posted,
          f"{total_completed}/{total_posted}")
    check("chaos: the storm actually bit (error completions + an "
          "aborted posting tenant)", total_errors > 0 and any_aborted,
          f"errors={total_errors} aborted={any_aborted}")
    check("chaos: every seeded storm replays byte-identically",
          all_identical, "")


# -------------------------------------------------------- recovery latency
def run_recovery() -> None:
    """Crash the destination mid-RAPF; the survivor must error out
    within the dead-round budget of timeout rounds."""
    config = FabricConfig(n_nodes=2)
    fab = Fabric.build(config)
    dom = fab.open_domain(1)
    cq = fab.create_cq()
    src = dom.register_memory(0, SRC, 65536, prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(1, DST, 65536, prep=BufferPrep.FAULTING)
    wr = dom.post_write(src, dst, cq=cq)
    crash_t = []

    def crash_when_paused():
        if any(b.state.name == "PAUSED_DST"
               for b in fab.nodes[0].r5.pending.values()):
            crash_t.append(fab.now)
            fab.crash_node(1)
            return
        fab.loop.schedule(1.0, crash_when_paused)

    fab.loop.schedule(1.0, crash_when_paused)
    wc = wr.result()
    recovery_us = fab.now - crash_t[0]
    # the detector charges one timeout round per dead round; +2 rounds of
    # slack cover the in-flight round at crash time and completion polling
    bound_us = (config.crash_detect_retries + 2) * config.cost.timeout_us
    emit("chaos/recovery_us", round(recovery_us, 3),
         f"crash -> {wc.status.value}")
    check("chaos: dead-peer detection errors out within the dead-round "
          "budget (no eternal retransmit)",
          wc.status == WCStatus.REMOTE_OP_ERR and recovery_us <= bound_us,
          f"{recovery_us:.0f}us <= {bound_us:.0f}us")


# --------------------------------------------------------- pager failover
def run_failover() -> None:
    pool = RemoteFramePool.build(
        n_frames=16, page_elems=32, n_pages=64,
        config=FabricConfig(n_nodes=4, topology="ring"),
        remote_node=1, replica_node=2)
    pool.page_out(None, 0, 8)            # mirrored write-backs
    pool.page_in(None, 0, 8)             # cold read warms the landing pages
    warm = pool.page_in(None, 0, 8).us
    pool.fabric.crash_node(1)
    rec = pool.page_in(None, 0, 8)
    emit("chaos/failover_warm_us", round(warm, 3), "pre-crash page-in")
    emit("chaos/failover_recovery_us", round(rec.us, 3),
         "failed-primary page-in via replica")
    check("chaos: replica failover serves the page-in (bytes intact)",
          rec.failovers == 1 and rec.bytes_in == 8 * pool.page_bytes,
          f"failovers={rec.failovers}")
    check("chaos: failover preserves read-your-writes (replica holds "
          "every mirrored write-back)",
          pool.ryw_verified >= 8 and pool.ryw_violations == 0,
          f"verified={pool.ryw_verified} violations={pool.ryw_violations}")
    check("chaos: failover recovery latency bounded (< 20x a warm "
          "page-in, detection included)", 0 < rec.us < 20 * warm,
          f"{rec.us:.1f}us vs warm {warm:.1f}us")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small storm for local iteration / CI fast job")
    args, _ = ap.parse_known_args()

    print("name,value,derived")
    if args.quick:
        run_storm(n_requests=4, crash_at=250.0)
    else:
        run_storm(n_requests=12, crash_at=900.0)
    run_recovery()
    run_failover()


if __name__ == "__main__":
    main()
