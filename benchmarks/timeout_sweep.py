"""Paper §4 timeout study: 25 ms / 2.5 ms / 1 ms retransmission timeouts
(1 ms best) + beyond-paper extensions: finer timeouts and the KERNEL_RAPF
/ STREAM resolvers the thesis lists as future work."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.core.addresses import TIMEOUT_SWEEP_US
from repro.api import BufferPrep
from repro.core.experiments import run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    lats = {}
    for to in TIMEOUT_SWEEP_US + (250.0, 100.0):
        r = run_remote_write(16384, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                             strategy=Strategy.TOUCH_A_PAGE, timeout_us=to)
        lats[to] = r.latency_us
        emit(f"timeout_sweep/src_tap/{to/1000:g}ms", r.latency_us,
             f"timeouts={r.stats.timeouts}")
    check("C7: 1ms beats 2.5ms beats 25ms (paper's sweep)",
          lats[1000.0] < lats[2500.0] < lats[25000.0])

    # beyond-paper: future-work resolvers on the dst-fault path
    base = run_remote_write(65536, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                            strategy=Strategy.TOUCH_AHEAD)
    kr = run_remote_write(65536, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                          strategy=Strategy.KERNEL_RAPF)
    st = run_remote_write(65536, BufferPrep.TOUCHED, BufferPrep.FAULTING,
                          strategy=Strategy.STREAM)
    emit("beyond/touch_ahead/64KB", base.latency_us, "paper mechanism")
    emit("beyond/kernel_rapf/64KB", kr.latency_us,
         "future-work #1: full-kernel path")
    emit("beyond/stream_prefetch/64KB", st.latency_us,
         "beyond-paper: next-block prediction")
    check("beyond-paper: kernel RAPF beats user-space RAPF hop",
          kr.latency_us < base.latency_us,
          f"{kr.latency_us:.0f} vs {base.latency_us:.0f}")
    check("beyond-paper: stream prefetch beats plain Touch-Ahead",
          st.latency_us <= kr.latency_us,
          f"{st.latency_us:.0f} vs {kr.latency_us:.0f}")


if __name__ == "__main__":
    main()
