"""THP study (thesis §3.1.2.3 — the motivation): khugepaged collapses
invalidate mappings of *pre-touched* buffers mid-run, so even the
touch-before-DMA discipline faults; only the handling mechanism (or full
pinning, with its costs) keeps transfers flowing.

Emulates a khugepaged pass between iterations over a 64 KB working set and
measures per-iteration transfer latency under: pre-touch discipline
without the mechanism's resolvers disabled (Touch-A-Page / Touch-Ahead)
vs pinned buffers (exempt from collapse, but paying pin/unpin).
"""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.core import addresses as A
from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy)

SIZE = 65536
SRC, DST, PD = 0x10_0000_0000, 0x20_0000_0000, 1


def run(strategy: Strategy, pinned: bool, iters: int = 8):
    fabric = Fabric.build(FabricConfig(
        n_nodes=1, default_policy=FaultPolicy(strategy=strategy)))
    dom = fabric.open_domain(PD)
    prep = BufferPrep.PINNED if pinned else BufferPrep.TOUCHED
    src = dom.register_memory(0, SRC, SIZE, prep=prep)
    dst = dom.register_memory(0, DST, SIZE, prep=prep)
    cq = fabric.create_cq(depth=4)
    pt = fabric.nodes[0].pt(PD)
    total = src.prep_cost.total_us + dst.prep_cost.total_us
    faults = 0
    for i in range(iters):
        # khugepaged scans between iterations: collapses both regions
        pt.khugepaged_collapse(A.page_index(SRC))
        pt.khugepaged_collapse(A.page_index(DST))
        t0 = fabric.now
        wr = dom.post_write(src, dst, cq=cq)
        wc = wr.result()
        cq.poll()
        total += wc.t_complete - t0
        faults += wr.stats.src_faults + wr.stats.dst_faults
    return total / iters, faults


def main() -> None:
    print("name,us_per_call,derived")
    lat_tap, f_tap = run(Strategy.TOUCH_A_PAGE, pinned=False)
    lat_ta, f_ta = run(Strategy.TOUCH_AHEAD, pinned=False)
    lat_pin, f_pin = run(Strategy.TOUCH_AHEAD, pinned=True)
    emit("thp/pretouched+touch_a_page", lat_tap, f"faults={f_tap}")
    emit("thp/pretouched+touch_ahead", lat_ta, f"faults={f_ta}")
    emit("thp/pinned", lat_pin, f"faults={f_pin}")
    check("THP: pre-touched buffers STILL fault under khugepaged "
          "(the thesis' motivation)", f_ta > 0, f"{f_ta} faults/8 iters")
    check("THP: pinned pages are exempt from collapse", f_pin == 0)
    check("THP: the mechanism keeps un-pinned transfers completing",
          lat_ta < 10_000, f"{lat_ta:.0f}us/iter with faults handled")
    check("THP: Touch-Ahead beats Touch-A-Page under THP churn",
          lat_ta < lat_tap, f"{lat_ta:.0f} vs {lat_tap:.0f}")


if __name__ == "__main__":
    main()
