"""Paper Fig 4.3: remote write with page fault at SOURCE — latency.
Source faults recover by timeout only: one timeout per page (Touch-A-Page)
vs per 16KB block (Touch-Ahead)."""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import BufferPrep
from repro.core.experiments import SIZES, run_remote_write
from repro.core.resolver import Strategy


def main() -> None:
    print("name,us_per_call,derived")
    ratios = {}
    for s in SIZES:
        tap = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                               strategy=Strategy.TOUCH_A_PAGE)
        ta = run_remote_write(s, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                              strategy=Strategy.TOUCH_AHEAD)
        ratios[s] = tap.latency_us / ta.latency_us
        emit(f"fig4.3/touch_a_page/{s}B", tap.latency_us,
             f"timeouts={tap.stats.timeouts}")
        emit(f"fig4.3/touch_ahead/{s}B", ta.latency_us,
             f"timeouts={ta.stats.timeouts};ratio={ratios[s]:.2f}")
    check("C4: src-fault benefit ~3.9x @16KB (paper 3.9x)",
          abs(ratios[16384] - 3.9) < 0.3, f"{ratios[16384]:.2f}")
    check("C4: src-fault benefit ~3.9x @32KB (paper 3.9x)",
          abs(ratios[32768] - 3.9) < 0.3, f"{ratios[32768]:.2f}")
    check("C4: src-fault benefit @64KB (paper 4.7x; interleave-dependent)",
          3.5 < ratios[65536] < 5.2, f"{ratios[65536]:.2f}")
    small = run_remote_write(16, BufferPrep.FAULTING, BufferPrep.TOUCHED,
                             strategy=Strategy.TOUCH_A_PAGE)
    check("C5: small transfers dominated by the 1ms timeout",
          0.85e3 < small.latency_us < 1.25e3, f"{small.latency_us:.0f}us")


if __name__ == "__main__":
    main()
