"""Shared benchmark plumbing: CSV emit + claim checks + JSON results."""

from __future__ import annotations

import json

CHECKS: list[tuple[str, bool, str]] = []
RESULTS: dict[str, float] = {}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS[name] = us_per_call
    print(f"{name},{us_per_call:.3f},{derived}")


def check(claim: str, ok: bool, detail: str = "") -> None:
    CHECKS.append((claim, ok, detail))
    print(f"# CHECK {'PASS' if ok else 'FAIL'}: {claim}  {detail}")


def summary() -> int:
    fails = [c for c in CHECKS if not c[1]]
    print(f"# {len(CHECKS) - len(fails)}/{len(CHECKS)} claim checks passed")
    return len(fails)


def write_json(path: str) -> None:
    """Machine-readable ``{name: us_per_call}`` (the BENCH_*.json
    perf-trajectory seed)."""
    with open(path, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
        f.write("\n")
