"""Shared benchmark plumbing: CSV emit + claim checks."""

from __future__ import annotations

CHECKS: list[tuple[str, bool, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def check(claim: str, ok: bool, detail: str = "") -> None:
    CHECKS.append((claim, ok, detail))
    print(f"# CHECK {'PASS' if ok else 'FAIL'}: {claim}  {detail}")


def summary() -> int:
    fails = [c for c in CHECKS if not c[1]]
    print(f"# {len(CHECKS) - len(fails)}/{len(CHECKS)} claim checks passed")
    return len(fails)
