"""Shared benchmark plumbing: CSV emit + claim checks + JSON results,
plus the ``--backend`` replay flag shared by every sweep driver."""

from __future__ import annotations

import argparse
import json

CHECKS: list[tuple[str, bool, str]] = []
RESULTS: dict[str, float] = {}


def add_backend_arg(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Register ``--backend {rapf,np_rdma,pin,pre_fault}`` on a parser."""
    from repro.core import experiments
    ap.add_argument(
        "--backend", choices=experiments.BACKENDS, default=None,
        help="replay every sweep under this fault-handling backend "
             "(default: each figure's own configuration)")
    return ap


def apply_backend(name) -> None:
    """Make ``name`` the process-wide default backend (no-op on None).

    Claim checks assert the *thesis* datapath's behaviour, so replaying
    under a different backend demotes check failures to informational
    lines instead of CI failures.
    """
    if name is None:
        return
    from repro.core import experiments
    experiments.set_default_backend(name)
    global _REPLAY_BACKEND
    _REPLAY_BACKEND = name if name != "rapf" else None


_REPLAY_BACKEND = None


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS[name] = us_per_call
    print(f"{name},{us_per_call:.3f},{derived}")


def check(claim: str, ok: bool, detail: str = "") -> None:
    if _REPLAY_BACKEND is not None:
        # replaying under a non-thesis backend: thesis claims don't apply
        print(f"# CHECK (info, backend={_REPLAY_BACKEND}) "
              f"{'PASS' if ok else 'FAIL'}: {claim}  {detail}")
        return
    CHECKS.append((claim, ok, detail))
    print(f"# CHECK {'PASS' if ok else 'FAIL'}: {claim}  {detail}")


def summary() -> int:
    fails = [c for c in CHECKS if not c[1]]
    print(f"# {len(CHECKS) - len(fails)}/{len(CHECKS)} claim checks passed")
    return len(fails)


def write_json(path: str) -> None:
    """Machine-readable ``{name: us_per_call}`` (the BENCH_*.json
    perf-trajectory seed)."""
    with open(path, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
        f.write("\n")
