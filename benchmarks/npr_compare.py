"""NP-RDMA backend head-to-head: speculation + DMA pool vs the thesis path.

Runs the same fig-4.x fault regimes under every ``--backend`` datapath
and pins the crossover points:

* **source faults** — the thesis path recovers by the 1 ms timeout only
  (Fig 4.3); NP-RDMA's host fixup re-pins and re-queues in microseconds,
  so NP-RDMA wins this regime outright;
* **destination faults** — RAPF retransmits after the resolver touches
  the pages; NP-RDMA aborts mid-flight and redirects through its
  pre-registered DMA pool, trading a page copy for the retransmit;
* **THP churn with a starved pool** — ``dma_pool_frames=4`` (one block's
  reservation) under khugepaged collapses: concurrent aborts find the
  pool dry, fall back to the 1 ms timeout, and RAPF wins — the
  provisioning lever the no-pinning design pays for;
* **torus congestion** — the abort/redirect control round-trip crosses a
  routed multi-hop fabric and still beats the timeout fallback.

Everything is deterministic per seed: the same configuration replayed
twice must produce byte-identical latencies and counters.
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import check, emit
from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy)
from repro.core import addresses as A
from repro.core.experiments import run_remote_write

SIZE = 65536
SRC, DST, PD = 0x10_0000_0000, 0x20_0000_0000, 1

SWEEP_SIZES = (1024, 4096, 16384, 65536)
QUICK_SIZES = (4096, 16384)

#: backends compared head-to-head (claim checks key off the first two)
CONTENDERS = ("rapf", "np_rdma", "pin", "pre_fault")


def _mean(xs) -> float:
    return sum(xs) / len(xs)


def fault_regime(where: str, sizes) -> dict:
    """One fig-4.x fault placement under every backend; mean latency."""
    src_prep = (BufferPrep.FAULTING if where in ("src", "both")
                else BufferPrep.TOUCHED)
    dst_prep = (BufferPrep.FAULTING if where in ("dst", "both")
                else BufferPrep.TOUCHED)
    means = {}
    for backend in CONTENDERS:
        lats = []
        for s in sizes:
            r = run_remote_write(s, src_prep, dst_prep,
                                 strategy=Strategy.TOUCH_AHEAD,
                                 backend=backend)
            lats.append(r.latency_us)
            detail = (f"timeouts={r.stats.timeouts}"
                      f";srcf={r.stats.src_faults}"
                      f";dstf={r.stats.dst_faults}")
            if backend == "np_rdma":
                detail += (f";aborts={r.stats.npr_aborts}"
                           f";redir={r.stats.pool_redirect_pages}"
                           f";stale={r.stats.mtt_stale}")
            emit(f"npr/{where}_fault/{backend}/{s}B", r.latency_us, detail)
        means[backend] = _mean(lats)
        emit(f"npr/{where}_fault/{backend}/mean", means[backend])
    return means


def churn_run(strategy: Strategy, dma_pool_frames: int = 64,
              iters: int = 8):
    """thp_study-style loop: khugepaged collapses the DESTINATION region
    between iterations, invalidating MTT entries (NP-RDMA) / mappings
    (RAPF).  Destination-only churn keeps RAPF on its fast NACK path
    (source faults would drag it into 1 ms timeouts and hide the pool
    crossover this regime exists to show)."""
    fabric = Fabric.build(FabricConfig(
        n_nodes=1, default_policy=FaultPolicy(strategy=strategy),
        dma_pool_frames=dma_pool_frames))
    dom = fabric.open_domain(PD)
    src = dom.register_memory(0, SRC, SIZE, prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(0, DST, SIZE, prep=BufferPrep.TOUCHED)
    cq = fabric.create_cq(depth=4)
    pt = fabric.nodes[0].pt(PD)
    total, agg = 0.0, {"timeouts": 0, "aborts": 0, "redirects": 0,
                       "stale": 0, "faults": 0}
    for _ in range(iters):
        pt.khugepaged_collapse(A.page_index(DST))
        t0 = fabric.now
        wr = dom.post_write(src, dst, cq=cq)
        wc = wr.result()
        cq.poll()
        total += wc.t_complete - t0
        agg["timeouts"] += wr.stats.timeouts
        agg["aborts"] += wr.stats.npr_aborts
        agg["redirects"] += wr.stats.pool_redirect_pages
        agg["stale"] += wr.stats.mtt_stale
        agg["faults"] += wr.stats.src_faults + wr.stats.dst_faults
    npr = fabric.protocol_stats()[0].npr
    return total / iters, agg, npr


def torus_run(backend: str):
    """Abort/redirect control traffic across a routed 3x3 torus."""
    return run_remote_write(
        SIZE, BufferPrep.TOUCHED, BufferPrep.FAULTING,
        strategy=Strategy.TOUCH_AHEAD, backend=backend, n_nodes=9,
        config_overrides={"topology": "torus_2d", "dims": (3, 3)})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep for the fast CI job")
    args, _ = ap.parse_known_args()
    sizes = QUICK_SIZES if args.quick else SWEEP_SIZES

    print("name,us_per_call,derived")

    # ---------------- fig-4.x fault regimes, all backends ----------------
    src_m = fault_regime("src", sizes)
    dst_m = fault_regime("dst", sizes)
    both_m = fault_regime("both", sizes)

    check("NPR: source faults — NP-RDMA's us-scale host fixup beats "
          "RAPF's 1ms-timeout-only recovery (crossover regime 1)",
          src_m["np_rdma"] < src_m["rapf"],
          f"np_rdma={src_m['np_rdma']:.1f}us rapf={src_m['rapf']:.1f}us")
    check("NPR: destination faults — abort+pool-redirect beats RAPF "
          "retransmission with a provisioned pool",
          dst_m["np_rdma"] < dst_m["rapf"],
          f"np_rdma={dst_m['np_rdma']:.1f}us rapf={dst_m['rapf']:.1f}us")
    check("NPR: faults at both ends — NP-RDMA still ahead (src fixup "
          "dominates the gap)", both_m["np_rdma"] < both_m["rapf"],
          f"np_rdma={both_m['np_rdma']:.1f}us rapf={both_m['rapf']:.1f}us")
    check("NPR: no free lunch — pinning beats every faulting backend "
          "once pin cost is excluded (Fig 4.1 baseline)",
          all(src_m["pin"] <= src_m[b] for b in ("rapf", "np_rdma")),
          f"pin={src_m['pin']:.1f}us")

    # ---------------- THP churn: provisioned vs starved pool -------------
    iters = 4 if args.quick else 8
    lat_rapf, agg_rapf, _ = churn_run(Strategy.TOUCH_AHEAD, iters=iters)
    lat_npr, agg_npr, eng = churn_run(Strategy.NP_RDMA,
                                      dma_pool_frames=64, iters=iters)
    lat_tiny, agg_tiny, eng_tiny = churn_run(Strategy.NP_RDMA,
                                             dma_pool_frames=4,
                                             iters=iters)
    emit("npr/thp_churn/rapf", lat_rapf, f"timeouts={agg_rapf['timeouts']}")
    emit("npr/thp_churn/np_rdma_pool64", lat_npr,
         f"aborts={agg_npr['aborts']};redir={agg_npr['redirects']}"
         f";stale={agg_npr['stale']}")
    emit("npr/thp_churn/np_rdma_pool4", lat_tiny,
         f"timeouts={agg_tiny['timeouts']}"
         f";stalls={eng_tiny.pool_stalls + eng_tiny.pool_reserve_failures}")
    check("NPR: khugepaged churn invalidates MTT entries and the "
          "verifier catches every one (stale hits > 0, zero stale "
          "completions)",
          agg_npr["stale"] > 0 and eng.stale_completions == 0,
          f"stale={agg_npr['stale']}")
    check("NPR: crossover regime 2 — a starved DMA pool "
          "(dma_pool_frames=4) stalls speculation into the timeout "
          "path and RAPF wins the churn workload",
          lat_tiny > lat_rapf,
          f"np_rdma/4={lat_tiny:.1f}us rapf={lat_rapf:.1f}us")
    check("NPR: the starved pool actually ran dry (reserve failures), "
          "it did not just get slower",
          eng_tiny.pool_reserve_failures > 0,
          f"failures={eng_tiny.pool_reserve_failures}")

    # ---------------- routed torus: multi-hop abort round-trip -----------
    t_npr = torus_run("np_rdma")
    t_rapf = torus_run("rapf")
    emit("npr/torus_dst_fault/np_rdma", t_npr.latency_us,
         f"aborts={t_npr.stats.npr_aborts}"
         f";redir={t_npr.stats.pool_redirect_pages}")
    emit("npr/torus_dst_fault/rapf", t_rapf.latency_us,
         f"timeouts={t_rapf.stats.timeouts}")
    check("NPR: abort/redirect control packets survive a routed "
          "multi-hop torus (aborts sent, zero timeout fallbacks)",
          t_npr.stats.npr_aborts > 0 and t_npr.stats.timeouts == 0,
          f"aborts={t_npr.stats.npr_aborts}")

    # ---------------- determinism ----------------------------------------
    a = run_remote_write(16384, BufferPrep.FAULTING, BufferPrep.FAULTING,
                         backend="np_rdma")
    b = run_remote_write(16384, BufferPrep.FAULTING, BufferPrep.FAULTING,
                         backend="np_rdma")
    same = (a.latency_us == b.latency_us
            and dataclasses.asdict(a.stats) == dataclasses.asdict(b.stats))
    check("NPR: identical configuration replays byte-identically "
          "(latency + every counter)", same,
          f"{a.latency_us:.3f}us vs {b.latency_us:.3f}us")


if __name__ == "__main__":
    main()
