"""Tenancy control plane at scale: 10k tenants, bank-steal crossover,
GOLD isolation under thrash (ISSUE-7 acceptance tier).

Three experiments:

* **Admission** — 10k protection domains (mixed GOLD/SILVER/BEST_EFFORT
  tiers) opened across a 128-node DRAGONFLY fabric, two nodes each.
  Emits admission throughput (tenants/s) and proves the new
  ``check_bank_conservation`` / ``check_tenant_isolation`` invariants on
  the fully-loaded fabric, plus ``TenantQuotaExceeded`` rejection once a
  node's ``tenants_per_node`` cap is hit.
* **Steal-rate crossover** — the same 2-node fabric driven by <= 16 hot
  domains binds every tenant eagerly (zero steals, seed-identical
  banks); 3x overcommitted, the LRU stealer kicks in (steals > 0) and
  the shootdown + rebind cost is visible in mean transfer latency.
* **GOLD isolation** — one GOLD tenant's p99 under full bank thrash
  stays within 2x its uncontended baseline: its bank is steal-immune
  and its blocks ride the LATENCY arbiter class.

Determinism: the thrash soak runs twice with the same seed and must be
byte-identical (the ``"tenancy"`` stats section included).
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import check, emit
from repro.api import (Fabric, FabricConfig, SLOClass, TenantQuotaExceeded)
from repro.core import addresses as A
from repro.testing import (TenantSpec, check_bank_conservation,
                           check_tenant_isolation, soak)

SEED = 2026


# --------------------------------------------------------- 10k admission
def slo_for(k: int) -> str:
    """Deterministic tier mix: sparse GOLD (the per-node GOLD cap keeps
    one bank stealable; the stride is prime and coprime to the node
    count, so GOLD tenants spread instead of clustering on one node),
    ~30% SILVER, the rest BEST_EFFORT."""
    if k % 97 == 0:
        return "gold"
    return "silver" if k % 10 < 3 else "best_effort"


def admission_tier(n_tenants: int) -> None:
    n_nodes = 128
    fab = Fabric.build(FabricConfig(
        n_nodes=n_nodes, topology="dragonfly", dims=(8, 16),
        frames_per_node=1 << 16, tenants_per_node=max(
            64, 4 * n_tenants // n_nodes)))
    t0 = time.perf_counter()
    golds = 0
    for k in range(n_tenants):
        slo = slo_for(k)
        golds += slo == "gold"
        fab.open_domain(k, slo=slo,
                        nodes=[k % n_nodes, (k + 1) % n_nodes])
    wall = time.perf_counter() - t0
    tps = n_tenants / wall if wall > 0 else 0.0
    emit("tenancy/admitted_tenants", n_tenants, f"{golds} GOLD")
    emit("tenancy/admission_tenants_per_s", round(tps, 1), "host rate")
    check(f"tenancy: {n_tenants} tenants admitted onto {n_nodes} nodes "
          f"(16 context banks each)", len(fab.domains) == n_tenants, "")
    bound = sum(n.tenancy.banks.bound_count() for n in fab.nodes)
    check("tenancy: every physical bank bound at full load "
          f"({n_nodes} nodes x 16)", bound == n_nodes * A.NUM_CONTEXT_BANKS,
          f"{bound}")
    v = check_bank_conservation(fab) + check_tenant_isolation(fab)
    check("tenancy: bank-conservation + tenant-isolation invariants hold "
          "on the fully-loaded fabric", v == [], "; ".join(v[:3]))

    # the admission cap actually rejects: hammer one node pair
    cap_fab = Fabric.build(FabricConfig(n_nodes=2, tenants_per_node=32))
    admitted = 0
    rejected = 0
    for k in range(40):
        try:
            cap_fab.open_domain(k)
            admitted += 1
        except TenantQuotaExceeded:
            rejected += 1
    check("tenancy: tenants_per_node cap rejects with "
          "TenantQuotaExceeded and admits exactly to the cap",
          admitted == 32 and rejected == 8,
          f"admitted={admitted} rejected={rejected}")
    check("tenancy: rejections are counted in admission telemetry",
          cap_fab.nodes[0].tenancy.admission_rejections == 8,
          f"{cap_fab.nodes[0].tenancy.admission_rejections}")


# ------------------------------------------------- steal-rate crossover
def tenant_specs(n: int, n_requests: int, gold_pd: int = 0):
    """n closed-loop tenants on a 2-node fabric; pd ``gold_pd`` is GOLD,
    the rest BEST_EFFORT.  Touched destinations: transfers exercise the
    bank-binding datapath without page-fault noise."""
    from repro.api import BufferPrep
    out = []
    for pd in range(n):
        out.append(TenantSpec(
            pd=pd, name=("gold" if pd == gold_pd else f"be{pd}"),
            slo=(SLOClass.GOLD if pd == gold_pd else SLOClass.BEST_EFFORT),
            mode="closed", inflight=1, n_requests=n_requests,
            size_choices=(16384,), dst_prep=BufferPrep.TOUCHED,
            fresh_dst=False, region_slots=2,
            src_node=pd % 2, dst_node=(pd + 1) % 2))
    return out


def bank_counters(result):
    binds = hits = steals = shootdowns = 0
    for node in result.fabric.nodes:
        st = node.tenancy.banks.stats
        binds += st.binds
        hits += st.hits
        steals += st.steals
        shootdowns += st.shootdowns
    return binds, hits, steals, shootdowns


def gold_stats(result):
    return next(t for t in result.stats["tenants"] if t["tenant"] == "gold")


def crossover_tier(n_requests: int) -> None:
    # LATENCY-class wire QoS on: the SLO contract is end-to-end, so
    # GOLD packets overtake BULK backlogs on the shared 2-node link
    cfg = lambda: FabricConfig(n_nodes=2, link_qos=True)
    # uncontended baseline: the GOLD tenant alone
    base = soak(SEED, tenants=tenant_specs(1, n_requests), config=cfg())
    base_gold = gold_stats(base)
    check("tenancy: uncontended baseline soak is clean", base.ok,
          "; ".join(base.violations[:3]))

    # <= 16 hot domains: eager seed-style binding, ZERO steals
    fit = soak(SEED, tenants=tenant_specs(14, n_requests), config=cfg())
    _, _, fit_steals, _ = bank_counters(fit)
    check("tenancy: <= 16 hot domains per node -> zero bank steals "
          "(seed-parity eager binding)", fit.ok and fit_steals == 0,
          f"steals={fit_steals}")

    # 3x overcommit: the LRU stealer must kick in
    thrash = soak(SEED, tenants=tenant_specs(48, n_requests),
                  config=cfg())
    binds, hits, steals, shootdowns = bank_counters(thrash)
    steal_rate = steals / binds if binds else 0.0
    emit("tenancy/thrash_steals", steals, f"of {binds} binds")
    emit("tenancy/thrash_steal_rate", round(steal_rate, 4),
         "steals per bind")
    check("tenancy: 3x bank overcommit -> steals > 0 with one shootdown "
          "per steal", thrash.ok and steals > 0 and shootdowns == steals,
          f"steals={steals} shootdowns={shootdowns}")

    # shootdown + rebind cost is visible in mean latency
    fit_mean = _mean_latency(fit, exclude="gold")
    thrash_mean = _mean_latency(thrash, exclude="gold")
    emit("tenancy/fit_mean_latency_us", round(fit_mean, 3),
         "14 tenants, no steals")
    emit("tenancy/thrash_mean_latency_us", round(thrash_mean, 3),
         "48 tenants, bank thrash")
    check("tenancy: bank thrash raises mean transfer latency "
          "(shootdown + rebind on the datapath)",
          thrash_mean > fit_mean,
          f"{thrash_mean:.2f} vs {fit_mean:.2f} us")

    # GOLD isolation: p99 within 2x uncontended under full thrash
    thrash_gold = gold_stats(thrash)
    emit("tenancy/gold_p99_base_us", base_gold["latency_p99_us"],
         "uncontended")
    emit("tenancy/gold_p99_thrash_us", thrash_gold["latency_p99_us"],
         "48-tenant bank thrash")
    check("tenancy: GOLD p99 under bank thrash <= 2x uncontended "
          "baseline (steal-immune bank + LATENCY class)",
          thrash_gold["latency_p99_us"]
          <= 2 * base_gold["latency_p99_us"],
          f"{thrash_gold['latency_p99_us']:.2f} vs "
          f"{base_gold['latency_p99_us']:.2f} us")
    check("tenancy: GOLD lost zero banks to stealing",
          all(n.tenancy.banks.stats.immune_steals == 0
              for n in thrash.fabric.nodes), "")

    # determinism: same seed -> byte-identical stats (tenancy included)
    again = soak(SEED, tenants=tenant_specs(48, n_requests), config=cfg())
    check("tenancy: thrash soak is byte-identical per seed",
          thrash.json() == again.json(), "")
    check("tenancy: thrash soak stats carry the tenancy section",
          "tenancy" in thrash.stats
          and thrash.stats["tenancy"]["node0"]["banks"]["steals"] > 0, "")


def _mean_latency(result, exclude: str) -> float:
    ts = [t for t in result.stats["tenants"] if t["tenant"] != exclude]
    lats = [t["latency_mean_us"] for t in ts if t["completed"]]
    return sum(lats) / len(lats) if lats else 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=10_000,
                    help="admission-tier tenant count")
    ap.add_argument("--quick", action="store_true",
                    help="small local iteration sizes (NOT the CI tier)")
    args, _ = ap.parse_known_args()
    n_tenants = 2_000 if args.quick else args.tenants
    n_requests = 6 if args.quick else 24

    print("name,value,derived")
    admission_tier(n_tenants)
    crossover_tier(n_requests)


if __name__ == "__main__":
    main()
