"""Topology-aware interconnect benchmark: control-packet distance fixes
and shared-link congestion under a fault storm.

Two claim families (ISSUE-4 acceptance criteria):

**A. Control-packet distance accounting.**  The seed charged ACK, NACK,
RAPF and read-request packets exactly one ``hop_latency_us`` regardless
of ``FabricConfig.hops`` — undercharging every fault-handling round trip
on any fabric deeper than one hop.  Post-fix, a clean write's RTT grows
by 2 legs (data + ACK) per extra hop and a faulted block's recovery by 4
legs (stream + RAPF + retransmit + ACK on the critical path), so the
*minimum safe retransmission timeout* — the smallest R5 timeout that
never fires before the RAPF arrives — shifts up with distance, exactly
the timeout/RAPF trade-off regime of the thesis (Fig 4.2/4.6).  The
seed's ALL_TO_ALL ``hops=1`` timing is preserved **bit-for-bit**
(golden-value checks recorded on the pre-PR tree).

**B. Shared-link contention on a torus.**  On a 2x4 torus a fault-storm
BULK tenant (0 -> 2, routed 0 -> 1 -> 2) shares link 0 -> 1 with a clean
LATENCY serving tenant (0 -> 1).  The storm's blocks and retransmits
measurably congest the shared link (queueing, utilization), while
LATENCY-class traffic — which overtakes BULK backlogs on every hop —
stays within 2x its uncongested baseline.
"""

from __future__ import annotations

from benchmarks.common import check, emit
from repro.api import (BufferPrep, Fabric, FabricConfig, ServiceClass)
from repro.core.costmodel import (DEFAULT_COST_MODEL,
                                  cost_model_with_timeout)
from repro.testing import TenantSpec, soak

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
HOP = DEFAULT_COST_MODEL.hop_latency_us
SEED = 2026

# ---- golden values recorded on the pre-PR tree (ALL_TO_ALL, hops=1) ----
GOLDEN_FAULT_65536 = (260.8803999999993, 4, 0, 13)   # latency, rapf, to, df
GOLDEN_CLEAN_16B = 4.002800000000001
# Re-recorded for the ID-lifecycle PR: the requests share one fabric, and
# completion callbacks now fire AT t_complete (the PLDMA status-poll
# return) instead of completion_poll_us before it, so each chained post
# starts 0.5 us later on the shared clock — element 3 sheds exactly the
# 0.5 us it previously spent waiting on absolute-time driver state, and
# element 2 moves one float ulp.  Single-write goldens above are
# untouched bit-for-bit.
GOLDEN_VECTOR = [7.2668, 44.9804, 260.8804000000002, 37.66960000000148,
                 56.41879999999969, 17.09719999999993]
GOLDEN_VECTOR_CASES = [(4096, BufferPrep.TOUCHED), (16384, BufferPrep.FAULTING),
                       (65536, BufferPrep.FAULTING), (4096, BufferPrep.FAULTING),
                       (65536, BufferPrep.TOUCHED), (16384, BufferPrep.TOUCHED)]


def one_write(fab: Fabric, nbytes: int, dst_prep: BufferPrep,
              slot: int = 0, src_node: int = 0, dst_node: int = 1):
    dom = fab.domain(1) or fab.open_domain(1)
    src = dom.register_memory(src_node, SRC + slot * 0x100000, nbytes,
                              prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(dst_node, DST + slot * 0x100000, nbytes,
                              prep=dst_prep)
    cq = fab.create_cq()
    return dom.post_write(src, dst, cq=cq).result(deadline_us=1e7)


def fault_write(hops: int, nbytes: int = 65536, timeout_us=None):
    cost = (cost_model_with_timeout(timeout_us)
            if timeout_us is not None else None)
    fab = Fabric.build(FabricConfig(n_nodes=2, hops=hops, cost=cost))
    return one_write(fab, nbytes, BufferPrep.FAULTING)


def clean_write(hops: int, nbytes: int = 16):
    fab = Fabric.build(FabricConfig(n_nodes=2, hops=hops))
    return one_write(fab, nbytes, BufferPrep.TOUCHED)


def min_safe_timeout(hops: int, lo: float = 10.0, hi: float = 120.0,
                     step: float = 0.5) -> float:
    """Smallest R5 timeout (us) for which a one-block destination fault
    recovers by RAPF alone — no spurious timeout retransmission."""
    t = lo
    while t <= hi:
        wc = fault_write(hops, nbytes=4096, timeout_us=t)
        if wc.stats.timeouts == 0:
            return t
        t += step
    return float("inf")


def torus_tenants(with_storm: bool):
    serving = TenantSpec(pd=1, name="serving",
                         service_class=ServiceClass.LATENCY,
                         mode="closed", inflight=2, n_requests=24,
                         size_choices=(4096,), src_node=0, dst_node=1,
                         src_prep=BufferPrep.TOUCHED,
                         dst_prep=BufferPrep.TOUCHED)
    if not with_storm:
        return [serving]
    # every 64 KB request lands in a fresh FAULTING region two routed
    # hops away: all four blocks fault, NACK, RAPF and retransmit over
    # the shared 0 -> 1 link
    storm = TenantSpec(pd=2, name="bulk-storm",
                       service_class=ServiceClass.BULK,
                       mode="closed", inflight=8, n_requests=16,
                       size_choices=(65536,), src_node=0, dst_node=2,
                       dst_prep=BufferPrep.FAULTING, fresh_dst=True)
    return [serving, storm]


TORUS = dict(n_nodes=8, topology="torus_2d", dims=(2, 4))


def main() -> None:
    print("name,us_per_call,derived")

    # ---------------- A. control-packet distance accounting -------------
    base_clean = clean_write(1)
    far_clean = clean_write(8)
    emit("net/clean_rtt_16B_hops1", base_clean.latency_us,
         "thesis 4us zero-fault RTT")
    emit("net/clean_rtt_16B_hops8", far_clean.latency_us,
         "data + ACK both charged 8 hops")
    clean_slope = (far_clean.latency_us - base_clean.latency_us) / 7
    check("net: clean-write RTT grows 2 x hop_latency per hop "
          "(ACK charged the routed distance, not one hop)",
          abs(clean_slope - 2 * HOP) < 1e-9,
          f"slope {clean_slope:.4f}us/hop vs {2 * HOP:.4f}")

    base_fault = fault_write(1, nbytes=4096)
    far_fault = fault_write(8, nbytes=4096)
    emit("net/fault_rtt_4K_hops1", base_fault.latency_us,
         f"rapf={base_fault.stats.rapf_retransmits}")
    emit("net/fault_rtt_4K_hops8", far_fault.latency_us,
         "stream+RAPF+retransmit+ACK all charged 8 hops")
    fault_slope = (far_fault.latency_us - base_fault.latency_us) / 7
    check("net: faulted-block recovery grows 4 x hop_latency per hop "
          "(RAPF/retransmit/ACK legs charged per routed hop)",
          abs(fault_slope - 4 * HOP) < 1e-9,
          f"slope {fault_slope:.4f}us/hop vs {4 * HOP:.4f}")

    to1 = min_safe_timeout(1)
    to16 = min_safe_timeout(16)
    emit("net/min_safe_timeout_hops1", to1, "smallest RAPF-only R5 timeout")
    emit("net/min_safe_timeout_hops16", to16,
         "distance-correct control path shifts the trade-off")
    # the timeout arms at dispatch; the legs before the RAPF arrives are
    # the data stream out (h) and the RAPF back (h) — the NACK overlaps
    # the driver's FIFO drain — so the safe floor shifts by 2 legs/hop
    check("net: minimum safe retransmission timeout shifts up with routed "
          "distance (thesis Fig 4.2/4.6 trade-off regime)",
          to16 >= to1 + 2 * 15 * HOP - 0.5,
          f"{to1:.1f}us @ 1 hop vs {to16:.1f}us @ 16 hops")

    # ---------------- back-compat: bit-for-bit at ALL_TO_ALL hops=1 -----
    wc = fault_write(1)
    got = (wc.latency_us, wc.stats.rapf_retransmits, wc.stats.timeouts,
           wc.stats.dst_faults)
    emit("net/backcompat_fault_65536", wc.latency_us,
         "golden pre-PR scenario")
    check("net: ALL_TO_ALL hops=1 reproduces the pre-PR faulting-block "
          "latency bit-for-bit", got == GOLDEN_FAULT_65536,
          f"{got} vs {GOLDEN_FAULT_65536}")
    check("net: ALL_TO_ALL hops=1 reproduces the pre-PR clean 16B RTT "
          "bit-for-bit", clean_write(1).latency_us == GOLDEN_CLEAN_16B,
          f"{clean_write(1).latency_us} vs {GOLDEN_CLEAN_16B}")
    fab = Fabric.build(FabricConfig(n_nodes=2))
    vec = [one_write(fab, n, p, slot=i).latency_us
           for i, (n, p) in enumerate(GOLDEN_VECTOR_CASES)]
    check("net: pre-PR mixed-size block-latency vector reproduced "
          "bit-for-bit", vec == GOLDEN_VECTOR, f"{vec}")

    # ---------------- B. torus shared-link congestion -------------------
    baseline = soak(SEED, tenants=torus_tenants(False),
                    config=FabricConfig(**TORUS))
    congested = soak(SEED, tenants=torus_tenants(True),
                     config=FabricConfig(**TORUS))
    congested2 = soak(SEED, tenants=torus_tenants(True),
                      config=FabricConfig(**TORUS))
    serv_base = baseline.stats["tenants"][0]
    serv_cong = congested.stats["tenants"][0]
    storm = congested.stats["tenants"][1]
    shared_base = baseline.stats["net"]["links"]["0->1"]
    shared_cong = congested.stats["net"]["links"]["0->1"]

    emit("net/torus_serving_baseline_mean", serv_base["latency_mean_us"],
         "LATENCY tenant alone on the 2x4 torus")
    emit("net/torus_serving_congested_mean", serv_cong["latency_mean_us"],
         f"vs fault storm routed over the shared 0->1 link")
    emit("net/torus_shared_link_queue_us", shared_cong["queue_us"],
         f"queued={shared_cong['queued']} "
         f"overtakes={shared_cong['latency_overtakes']}")
    emit("net/torus_storm_mean", storm["latency_mean_us"],
         f"rapf={storm['rapf_retransmits']} timeouts={storm['timeouts']}")

    check("net: the fault storm measurably congests the shared torus "
          "link (wire queueing appears where the baseline had none)",
          shared_cong["queue_us"] > 10.0 * max(shared_base["queue_us"], 1.0)
          and shared_cong["data_bytes"] > 4 * shared_base["data_bytes"],
          f"queue {shared_base['queue_us']:.1f} -> "
          f"{shared_cong['queue_us']:.1f}us, bytes "
          f"{shared_base['data_bytes']} -> {shared_cong['data_bytes']}")
    check("net: storm retransmits traverse the shared link (RAPF "
          "recovery active)", storm["rapf_retransmits"] > 0,
          f"rapf={storm['rapf_retransmits']}")
    check("net: LATENCY-class traffic overtakes BULK backlogs on the "
          "congested hop", shared_cong["latency_overtakes"] > 0,
          f"overtakes={shared_cong['latency_overtakes']}")
    check("net: LATENCY fault-resolution RTT stays within 2x its "
          "uncongested baseline on the congested torus",
          serv_cong["latency_mean_us"] <= 2.0 * serv_base["latency_mean_us"],
          f"{serv_cong['latency_mean_us']:.1f}us vs "
          f"2 x {serv_base['latency_mean_us']:.1f}us")
    check("net: torus soak invariants hold (conservation, arbiter, pins)",
          baseline.ok and congested.ok,
          "; ".join((baseline.violations + congested.violations)[:3]))
    check("net: torus congestion run is seed-deterministic "
          "(byte-identical stats)",
          congested.json() == congested2.json(), "")


if __name__ == "__main__":
    main()
